"""Loop-aware HLO analysis: the empirical facts it exists to correct, and
its own correctness on compiled modules and synthetic HLO text."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_analysis import (analyze_module, parse_module,
                                     xla_cost_analysis)
from repro.core.hlo_flows import (CollectiveFlow, find_redundant_gathers,
                                  parse_collective_flows)


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


_cost = xla_cost_analysis


class TestLoopAwareness:
    def test_xla_cost_analysis_counts_while_body_once(self):
        """The bug this module corrects — if XLA ever fixes it, this test
        tells us to simplify."""
        x = jnp.zeros((256, 256))
        w = jnp.zeros((256, 256))

        def one(x, w):
            return x @ w

        def scanned(x, w):
            y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None,
                                length=10)
            return y

        f1 = _cost(_compile(one, x, w))["flops"]
        f10 = _cost(_compile(scanned, x, w))["flops"]
        assert f1 == f10  # body counted once despite 10 trips

    def test_flat_scan_flops(self):
        x = jnp.zeros((256, 256))
        w = jnp.zeros((256, 256))

        def scanned(x, w):
            y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None,
                                length=10)
            return y

        mc = analyze_module(_compile(scanned, x, w).as_text())
        assert mc.flops == pytest.approx(10 * 2 * 256 ** 3, rel=0.01)

    def test_nested_scan_flops_multiply(self):
        x = jnp.zeros((128, 128))
        w = jnp.zeros((128, 128))

        def nested(x, w):
            def outer(c, _):
                c, _ = jax.lax.scan(lambda c2, _: (c2 @ w, None), c, None,
                                    length=5)
                return c, None
            y, _ = jax.lax.scan(outer, x, None, length=3)
            return y

        mc = analyze_module(_compile(nested, x, w).as_text())
        assert mc.flops == pytest.approx(15 * 2 * 128 ** 3, rel=0.01)

    def test_unrolled_matches_scanned(self):
        x = jnp.zeros((128, 128))
        w = jnp.zeros((128, 128))

        def unrolled(x, w):
            for _ in range(4):
                x = x @ w
            return x

        def scanned(x, w):
            y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=4)
            return y

        f_un = analyze_module(_compile(unrolled, x, w).as_text()).flops
        f_sc = analyze_module(_compile(scanned, x, w).as_text()).flops
        assert f_un == pytest.approx(f_sc, rel=0.01)

    def test_dot_general_contraction(self):
        a = jnp.zeros((4, 64, 32))
        b = jnp.zeros((4, 32, 16))
        mc = analyze_module(_compile(jnp.matmul, a, b).as_text())
        assert mc.flops == pytest.approx(2 * 4 * 64 * 32 * 16, rel=0.01)


SYNTH_HLO = """
HloModule test

ENTRY %main (p0: f32[1024,512]) -> f32[1024,512] {
  %p0 = f32[1024,512]{1,0} parameter(0)
  %ag = f32[1024,512]{1,0} all-gather(%p0), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}, metadata={op_name="jit(f)/mlp/gather"}
  %ar = f32[1024,512]{1,0} all-reduce(%ag), channel_id=2, replica_groups=[16,16]<=[256]T(1,0), to_apply=%add, metadata={op_name="jit(f)/attention/psum"}
  ROOT %cp = f32[1024,512]{1,0} copy(%ar)
}
"""


class TestCollectiveParsing:
    def test_synthetic_module(self):
        mc = analyze_module(SYNTH_HLO, ("mlp", "attention"),
                            {"data": 16, "model": 16})
        assert mc.n_collectives == 2
        kinds = set(mc.by_kind_wire)
        assert kinds == {"all-gather", "all-reduce"}
        bytes_t = 1024 * 512 * 4
        assert mc.by_kind_wire["all-gather"] == pytest.approx(
            bytes_t * 15 / 16)
        assert mc.by_kind_wire["all-reduce"] == pytest.approx(
            2 * bytes_t * 15 / 16)
        # iota groups without transpose = contiguous ids = innermost axis
        assert mc.by_axis_wire.get("model", 0) > 0
        assert mc.by_axis_wire.get("data", 0) > 0
        assert mc.by_component_wire["mlp"] > 0
        assert mc.by_component_wire["attention"] > 0

    def test_real_psum_collective(self):
        # single-device "collective": XLA elides it; just check no crash
        mc = analyze_module(_compile(lambda x: x * 2,
                                     jnp.zeros((8, 8))).as_text())
        assert mc.wire_bytes == 0.0

    def test_redundancy_detector(self):
        flows = [CollectiveFlow("all-gather", "a", 100, 100, 4, 1, "x",
                                "mlp", "model")] * 3
        red = find_redundant_gathers(flows)
        assert red and red[0][1] == 3


class TestByteModel:
    def test_update_slice_counts_update_region_only(self):
        big = jnp.zeros((1024, 1024))
        small = jnp.ones((8, 1024))

        def f(big, small):
            return jax.lax.dynamic_update_slice(big, small, (0, 0))

        # donate the buffer: without donation XLA inserts a defensive full
        # copy (which IS real traffic and would be counted)
        c = jax.jit(f, donate_argnums=(0,)).lower(big, small).compile()
        mc = analyze_module(c.as_text())
        # must NOT count the 4 MB buffer, only ~2x the 32 KB update
        assert mc.io_bytes < 1024 * 1024 * 4
