"""zamba2-2.7b — Mamba2 backbone + weight-tied shared attention block applied
every 6 layers [arXiv:2411.15242]. d_ff applies to the shared block's MLP.
DESIGN.md notes the per-invocation LoRA on the shared block is simplified away.
"""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80,
    ssm_state=64, d_inner=5120, ssm_head_dim=64, ssm_chunk=128,
    attn_every=6,
).validate()


def smoke():
    return reduced(CONFIG, n_kv_heads=4)
