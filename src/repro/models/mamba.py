"""Mamba2 (SSD) blocks + the Zamba2 hybrid backbone.

Mamba2 block [arXiv:2405.21060]: in_proj -> (z, x, B, C, dt); causal
depthwise conv over (x, B, C); silu; SSD scan (Pallas kernel on TPU, chunked
jnp oracle on CPU — kernels/ops.ssd_scan); D skip; silu(z) gate; group
RMSNorm; out_proj.

Zamba2 [arXiv:2411.15242]: a stack of Mamba2 layers with ONE weight-tied
attention(+MLP) block applied every `attn_every` layers. The shared block's
params are closed over (not scanned); the Mamba stack is scanned as
[n_super, attn_every, ...]. DESIGN.md records the simplification vs the
published model (single shared block, per-invocation LoRA omitted).

Decode state is O(1) in sequence length: conv tail [B, K-1, ch] + SSD state
h [B, H, N, P] per layer; the shared attention block keeps a standard KV
cache per invocation ([n_super, B, Hkv, S, hd]) — for long_500k that cache is
what gets sequence-sharded (context parallelism).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.device_fold import DeviceFoldSpec, annotate_cost, scan_multiplier
from repro.kernels import ops
from repro.parallel.axes import shard

from .layers import (Params, Runtime, _init, attention, cross_entropy, embed,
                     init_attention, init_embed, init_lm_head, init_mlp,
                     init_norm, last_valid, lm_head, linear, mlp, norm,
                     pdtype)


# ------------------------------------------------------------ mamba block ----
def init_mamba_block(key, cfg: ModelConfig) -> Params:
    d, di, n = cfg.d_model, cfg.d_inner_, cfg.ssm_state
    heads = cfg.n_ssm_heads
    ks = jax.random.split(key, 4)
    dt = pdtype(cfg)
    conv_ch = di + 2 * n
    p = {
        "in_proj": _init(ks[0], (d, 2 * di + 2 * n + heads), dt),
        "conv_w": _init(ks[1], (cfg.conv_kernel, conv_ch), dt,
                        scale=cfg.conv_kernel ** -0.5),
        "out_proj": _init(ks[2], (di, d), dt),
        "a_log": jnp.zeros((heads,), jnp.float32),       # A = -exp(a_log)
        "dt_bias": jnp.full((heads,), -2.0, jnp.float32),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "norm": jnp.ones((di,), dt),
    }
    return {"norm1": init_norm(cfg), "ssm": p}


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv. x: [B, L, ch], w: [K, ch].
    state: [B, K-1, ch] tail of previous tokens (decode). Returns (y, new
    state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)               # [B, L+K-1, ch]
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    new_state = xp[:, -(K - 1):]
    return y, new_state


def mamba_block(p: Params, x: jax.Array, rt: Runtime,
                state: Optional[Params] = None, return_state: bool = False,
                valid: Optional[jax.Array] = None):
    """x: [B, L, d] -> (y, new_state).

    state None = full-sequence mode (training / fresh prefill);
    state + L == 1 = O(1) decode recurrence; state + L > 1 = positioned
    prefill CHUNK — the SSD scan resumes from the carried h, the causal
    conv from the carried tail, so feeding a prompt in chunks is the same
    recurrence as feeding it whole.  valid: [B] real-token counts of a
    bucket-padded chunk — pad steps get dt = 0 (decay 1, zero injection:
    state untouched) and the conv tail is gathered at each row's own
    valid frontier.  return_state=True materializes the post-sequence
    state so prefill can hand off to decode."""
    cfg = rt.cfg
    sp = p["ssm"]
    B, L, d = x.shape
    di, n, heads = cfg.d_inner_, cfg.ssm_state, cfg.n_ssm_heads
    ph = cfg.ssm_head_dim
    K = cfg.conv_kernel
    with jax.named_scope("ssm"):
        h = norm(p["norm1"], x, rt)
        proj = linear(sp["in_proj"], h)
        z = proj[..., :di]
        raw_xbc = proj[..., di:di + di + 2 * n]
        dt_raw = proj[..., -heads:]
        annotate_cost("ssm", "ssm", "in_proj",
                      flops=2.0 * B * L * d * (2 * di + 2 * n + heads))

        conv_state = state["conv"] if state is not None else None
        xbc, new_conv = _causal_conv(raw_xbc, sp["conv_w"].astype(x.dtype),
                                     conv_state)
        xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
        xs = xbc[..., :di].reshape(B, L, heads, ph)
        b_mat = xbc[..., di:di + n]
        c_mat = xbc[..., di + n:]

        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + sp["dt_bias"][None, None])
        if valid is not None:
            # pad steps must not advance the state: dt = 0 decays by
            # exp(0) = 1 and injects 0 (the same trick ops.ssd_scan uses
            # for its own internal chunk-multiple padding)
            real = jnp.arange(L)[None, :, None] \
                < jnp.asarray(valid, jnp.int32)[:, None, None]
            dt = jnp.where(real, dt, 0.0)
        a = -jnp.exp(sp["a_log"])

        if state is None or L > 1:
            y, h_final = ops.ssd_scan(xs, dt, a, b_mat, c_mat,
                                      chunk=min(cfg.ssm_chunk, L),
                                      h0=state["h"] if state is not None
                                      else None,
                                      impl=rt.impl)
            new_ssm = h_final
            if return_state or state is not None:
                conv_tail = _conv_tail(raw_xbc, conv_state, K, valid)
        else:
            # single-step recurrence (decode): L == 1
            h_prev = state["h"]                           # [B, H, N, P] f32
            dt1 = dt[:, 0]                                # [B, H]
            decay = jnp.exp(a[None] * dt1)                # [B, H]
            dbx = jnp.einsum("bh,bn,bhp->bhnp", dt1,
                             b_mat[:, 0].astype(jnp.float32),
                             xs[:, 0].astype(jnp.float32))
            h_new = decay[..., None, None] * h_prev + dbx
            y = jnp.einsum("bn,bhnp->bhp", c_mat[:, 0].astype(jnp.float32),
                           h_new)[:, None].astype(x.dtype)
            new_ssm = h_new
            y = y.reshape(B, 1, heads, ph)
            conv_tail = new_conv

        y = y.astype(jnp.float32) + sp["d_skip"][None, None, :, None] \
            * xs.astype(jnp.float32)
        y = y.reshape(B, L, di)
        y = y * jax.nn.silu(z.astype(jnp.float32))
        y = ops.rmsnorm(y.astype(x.dtype), sp["norm"], eps=cfg.norm_eps,
                        impl=rt.impl)
        out = linear(sp["out_proj"], y)
        annotate_cost("ssm", "ssm", "out_proj", flops=2.0 * B * L * di * d)
        if state is not None:
            new_state = {"conv": conv_tail.astype(state["conv"].dtype),
                         "h": new_ssm}
        elif return_state:
            new_state = {"conv": conv_tail, "h": new_ssm}
        else:
            new_state = None
        return shard(out, "batch", "seq", None), new_state


def _conv_tail(raw_xbc: jax.Array, conv_state: Optional[jax.Array], K: int,
               valid: Optional[jax.Array]) -> jax.Array:
    """The K-1 PRE-silu conv inputs ending at each row's valid frontier.

    raw_xbc: [B, L, ch] this chunk's raw conv inputs; conv_state: the
    previous chunk's tail (None = fresh sequence) — needed when L < K-1;
    valid: [B] per-row real-token counts (None = L)."""
    B, L, ch = raw_xbc.shape
    pad = (jnp.zeros((B, K - 1, ch), raw_xbc.dtype) if conv_state is None
           else conv_state.astype(raw_xbc.dtype))
    xp = jnp.concatenate([pad, raw_xbc], axis=1)         # [B, K-1+L, ch]
    if valid is None:
        return xp[:, -(K - 1):]
    take = lambda row, v: jax.lax.dynamic_slice_in_dim(row, v, K - 1, axis=0)
    return jax.vmap(take)(xp, jnp.asarray(valid, jnp.int32))


def init_mamba_state(cfg: ModelConfig, batch: int, n_layers: int,
                     dtype=jnp.float32) -> Params:
    di, n = cfg.d_inner_, cfg.ssm_state
    heads, ph = cfg.n_ssm_heads, cfg.ssm_head_dim
    conv_ch = di + 2 * n
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.conv_kernel - 1, conv_ch),
                          dtype),
        "h": jnp.zeros((n_layers, batch, heads, n, ph), jnp.float32),
    }


# ---------------------------------------------------------- zamba2 hybrid ----
def init_params(key, cfg: ModelConfig) -> Params:
    """Zamba2: scanned mamba stack [n_super, attn_every, ...] + ONE shared
    attention/MLP block."""
    assert cfg.attn_every > 0
    n_super = cfg.n_layers // cfg.attn_every
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {}
    p.update(init_embed(ks[0], cfg))
    p.update(init_lm_head(ks[1], cfg))
    p["final_norm"] = init_norm(cfg)
    lkeys = jax.random.split(ks[2], cfg.n_layers).reshape(
        n_super, cfg.attn_every)
    stack = jax.vmap(jax.vmap(
        functools.partial(init_mamba_block, cfg=cfg)))(lkeys)
    p["stack"] = {"stack": stack}
    shared: Dict[str, Any] = {"norm1": init_norm(cfg), "norm2": init_norm(cfg)}
    shared.update(init_attention(ks[3], cfg))
    shared.update(init_mlp(ks[4], cfg))
    p["shared_attn"] = shared
    return p


def _shared_block(shared: Params, x: jax.Array, rt: Runtime,
                  positions: jax.Array, cache=None, pos=None):
    h = norm(shared["norm1"], x, rt)
    a, new_cache = attention(shared, h, rt, positions, cache=cache, pos=pos)
    x = x + a
    h = norm(shared["norm2"], x, rt)
    x = x + mlp(shared, h, rt)
    return x, new_cache


def forward(p: Params, tokens: jax.Array, rt: Runtime, table: jax.Array,
            prefix_embeds=None):
    cfg = rt.cfg
    n_super = cfg.n_layers // cfg.attn_every
    x = embed(p, tokens, rt)
    S = x.shape[1]
    positions = jnp.arange(S)
    shared = p["shared_attn"]

    def super_body(carry, super_p):
        x, table = carry

        def inner(carry2, layer_p):
            x2, = carry2
            y, _ = mamba_block(layer_p, x2, rt)
            return (x2 + y,), None

        with scan_multiplier(cfg.attn_every):
            (x,), _ = jax.lax.scan(inner, (x,), super_p)
        x, _ = _shared_block(shared, x, rt, positions)
        return (x, table), None

    if cfg.remat != "none":
        super_body = jax.checkpoint(
            super_body, policy=jax.checkpoint_policies.dots_saveable
            if cfg.remat == "dots_saveable" else None)
    with scan_multiplier(n_super):
        (x, table), _ = jax.lax.scan(super_body, (x, table),
                                     p["stack"]["stack"])
    x = norm(p["final_norm"], x, rt)
    return x, table, jnp.float32(0.0)


def loss_fn(p: Params, batch, rt: Runtime, table: jax.Array):
    x, table, aux = forward(p, batch["tokens"], rt, table)
    logits = lm_head(p, x, rt)
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss + aux, ({"loss": loss, "aux_loss": aux}, table)


# -------------------------------------------------------------- serving ----
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    n_super = cfg.n_layers // cfg.attn_every
    hd = cfg.head_dim_
    return {
        "ssm": init_mamba_state(cfg, batch, cfg.n_layers, dtype),
        "attn_k": jnp.zeros((n_super, batch, cfg.n_kv_heads, max_len, hd),
                            dtype),
        "attn_v": jnp.zeros((n_super, batch, cfg.n_kv_heads, max_len, hd),
                            dtype),
    }


def forward_chunk(p: Params, tokens: jax.Array, rt: Runtime, table: jax.Array,
                  cache: Params, pos: jax.Array,
                  valid: Optional[jax.Array] = None):
    """Positioned-chunk forward: tokens [B, T] written at per-slot offsets
    pos [B] (scalar broadcasts); valid [B] masks a bucket-padded chunk.

    The SSM stacks resume their recurrences from the carried (conv, h)
    state — position-free, row-independent by construction — while the
    shared attention block scatters T K/V rows at each row's own offset
    and attends offset-causally; T = 1 is the pooled decode recurrence,
    pos = 0 with T = prompt length is bulk prefill."""
    cfg = rt.cfg
    n_super = cfg.n_layers // cfg.attn_every
    k = cfg.attn_every
    x = embed(p, tokens, rt)
    B, T = x.shape[:2]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None] + jnp.arange(T)[None, :]   # [B, T] per-row rope
    shared = p["shared_attn"]
    ssm0 = jax.tree.map(
        lambda a: a.reshape((n_super, k) + a.shape[1:]), cache["ssm"])

    def super_body(carry, inp):
        x, table = carry
        super_p, ssm_seg, kc, vc = inp

        def inner(carry2, inp2):
            x2, = carry2
            layer_p, st = inp2
            y, new_st = mamba_block(layer_p, x2, rt, state=st, valid=valid)
            return (x2 + y,), new_st

        with scan_multiplier(k):
            (x,), new_seg = jax.lax.scan(inner, (x,), (super_p, ssm_seg))
        x, new_kv = _shared_block(shared, x, rt, positions,
                                  cache={"k": kc, "v": vc}, pos=pos)
        return (x, table), (new_seg, new_kv["k"], new_kv["v"])

    with scan_multiplier(n_super):
        (x, table), (new_ssm, nk, nv) = jax.lax.scan(
            super_body, (x, table),
            (p["stack"]["stack"], ssm0, cache["attn_k"], cache["attn_v"]))

    x = norm(p["final_norm"], x, rt)
    logits = lm_head(p, last_valid(x, valid), rt)[:, 0]
    new_cache = {
        "ssm": jax.tree.map(
            lambda a: a.reshape((n_super * k,) + a.shape[2:]), new_ssm),
        "attn_k": nk, "attn_v": nv,
    }
    return logits, new_cache, table


def prefill(p: Params, tokens: jax.Array, rt: Runtime, table: jax.Array,
            cache: Params, prefix_embeds=None):
    """Bulk prefill = forward_chunk at offset 0 with T = prompt length."""
    zero = jnp.zeros((tokens.shape[0],), jnp.int32)
    return forward_chunk(p, tokens, rt, table, cache, zero)


def decode_step(p: Params, token: jax.Array, rt: Runtime, table: jax.Array,
                cache: Params, pos: jax.Array):
    """Pooled decode = forward_chunk at width T = 1.  token: [B]."""
    return forward_chunk(p, token[:, None], rt, table, cache, pos)


def declare_fold_slots(spec: DeviceFoldSpec, cfg: ModelConfig) -> None:
    spec.declare("app", "loss", "train_step", "count")
