"""Serve a small model with continuously-batched requests.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import ServeConfig
from repro.core.session import XFASession
from repro.models import build_model
from repro.serving.engine import ServingEngine


def main():
    cfg = get_smoke("tinyllama_1_1b")
    model = build_model(cfg, impl="auto")
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params,
                           ServeConfig(max_batch=4, max_seq_len=128))
    rng = np.random.default_rng(0)
    reqs = [engine.submit(rng.integers(0, cfg.vocab, n_prompt),
                          max_new_tokens=8)
            for n_prompt in (12, 20, 7, 16, 9, 14)]
    t0 = time.monotonic()
    done = engine.run_until_drained()
    dt = time.monotonic() - t0
    tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {tokens} tokens "
          f"in {dt:.2f}s ({tokens/dt:.1f} tok/s on CPU)")
    for r in done:
        ttft = (r.first_token_at - r.submitted_at) * 1e3
        print(f"  req {r.uid}: prompt {len(r.prompt):3d} -> "
              f"{len(r.output)} tokens, ttft {ttft:.0f}ms")


if __name__ == "__main__":
    main()
