"""Encoder-decoder transformer (seamless-m4t-large-v2 backbone).

The speech frontend is a STUB per the assignment: input_specs supplies
precomputed frame embeddings [B, S_src, frontend_dim]; a learned projection
maps them into d_model. Encoder: bidirectional self-attention (RoPE) + MLP.
Decoder: causal self-attention + cross-attention over encoder output + MLP,
all scanned over layers.

Serving: prefill encodes the source ONCE and caches, per decoder layer, both
the self-attn KV (grows with decoding) and the cross-attn K/V (static,
computed from the encoder output once — the standard enc-dec serving
optimization). decode_step touches only cached tensors.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.device_fold import DeviceFoldSpec, annotate_cost, scan_multiplier
from repro.kernels import ops
from repro.parallel.axes import shard

from .layers import (Params, Runtime, attention, cross_entropy, embed,
                     init_attention, init_embed, init_lm_head, init_mlp,
                     init_norm, last_valid, lm_head, linear, mlp, norm,
                     _init, pdtype)


def init_encoder_layer(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"norm1": init_norm(cfg), "norm2": init_norm(cfg)}
    p.update(init_attention(k1, cfg))
    p.update(init_mlp(k2, cfg))
    return p


def init_decoder_layer(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm1": init_norm(cfg), "norm2": init_norm(cfg),
         "norm3": init_norm(cfg)}
    p.update(init_attention(k1, cfg))
    p["cross"] = init_attention(k2, cfg)
    p.update(init_mlp(k3, cfg))
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {}
    p.update(init_embed(ks[0], cfg))
    p.update(init_lm_head(ks[1], cfg))
    p["final_norm"] = init_norm(cfg)
    p["enc_norm"] = init_norm(cfg)
    p["frontend"] = {"w": _init(ks[2], (cfg.frontend_dim, cfg.d_model),
                                pdtype(cfg))}
    ekeys = jax.random.split(ks[3], cfg.enc_layers)
    dkeys = jax.random.split(ks[4], cfg.dec_layers)
    p["enc_stack"] = {"stack": jax.vmap(
        functools.partial(init_encoder_layer, cfg=cfg))(ekeys)}
    p["dec_stack"] = {"stack": jax.vmap(
        functools.partial(init_decoder_layer, cfg=cfg))(dkeys)}
    return p


def encode(p: Params, frames: jax.Array, rt: Runtime) -> jax.Array:
    """frames: [B, S_src, frontend_dim] -> [B, S_src, d]."""
    cfg = rt.cfg
    with jax.named_scope("encoder"):
        with jax.named_scope("embed"):
            x = linear(p["frontend"]["w"], frames.astype(rt.cdtype))
            x = shard(x, "batch", "seq", None)
        positions = jnp.arange(x.shape[1])

        def body(carry, layer_p):
            x, = carry
            h = norm(layer_p["norm1"], x, rt)
            a, _ = attention(layer_p, h, rt, positions, causal=False)
            x = x + a
            h = norm(layer_p["norm2"], x, rt)
            x = x + mlp(layer_p, h, rt)
            return (x,), None

        if cfg.remat != "none":
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.dots_saveable
                                  if cfg.remat == "dots_saveable" else None)
        with scan_multiplier(cfg.enc_layers):
            (x,), _ = jax.lax.scan(body, (x,), p["enc_stack"]["stack"])
        return norm(p["enc_norm"], x, rt)


def decode_train(p: Params, tokens: jax.Array, enc_out: jax.Array,
                 rt: Runtime, table: jax.Array):
    cfg = rt.cfg
    with jax.named_scope("decoder"):
        x = embed(p, tokens, rt)
        positions = jnp.arange(x.shape[1])

        def body(carry, layer_p):
            x, table = carry
            h = norm(layer_p["norm1"], x, rt)
            a, _ = attention(layer_p, h, rt, positions, causal=True)
            x = x + a
            with jax.named_scope("cross"):
                h = norm(layer_p["norm2"], x, rt)
                a, _ = attention(layer_p["cross"], h, rt, positions,
                                 kv=enc_out, causal=False)
                x = x + a
            h = norm(layer_p["norm3"], x, rt)
            x = x + mlp(layer_p, h, rt)
            return (x, table), None

        if cfg.remat != "none":
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.dots_saveable
                                  if cfg.remat == "dots_saveable" else None)
        with scan_multiplier(cfg.dec_layers):
            (x, table), _ = jax.lax.scan(body, (x, table),
                                         p["dec_stack"]["stack"])
        return norm(p["final_norm"], x, rt), table


def forward(p: Params, tokens: jax.Array, rt: Runtime, table: jax.Array,
            frames: Optional[jax.Array] = None):
    enc_out = encode(p, frames, rt)
    x, table = decode_train(p, tokens, enc_out, rt, table)
    return x, table, jnp.float32(0.0)


def loss_fn(p: Params, batch, rt: Runtime, table: jax.Array):
    x, table, aux = forward(p, batch["tokens"], rt, table,
                            frames=batch["frames"])
    logits = lm_head(p, x, rt)
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss + aux, ({"loss": loss, "aux_loss": aux}, table)


# ---------------------------------------------------------------- serving ----
def init_cache(cfg: ModelConfig, batch: int, max_len: int, src_len: int = 0,
               dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    hd = cfg.head_dim_
    L = cfg.dec_layers
    src = src_len or max_len
    return {
        "k": jnp.zeros((L, batch, cfg.n_kv_heads, max_len, hd), dtype),
        "v": jnp.zeros((L, batch, cfg.n_kv_heads, max_len, hd), dtype),
        "xk": jnp.zeros((L, batch, cfg.n_kv_heads, src, hd), dtype),
        "xv": jnp.zeros((L, batch, cfg.n_kv_heads, src, hd), dtype),
    }


def _cross_kv(layer_p: Params, enc_out: jax.Array, cfg: ModelConfig):
    B, Sk, _ = enc_out.shape
    hd = cfg.head_dim_
    ap = layer_p["cross"]["attn"]
    k = linear(ap["wk"], enc_out).reshape(B, Sk, cfg.n_kv_heads, hd)
    v = linear(ap["wv"], enc_out).reshape(B, Sk, cfg.n_kv_heads, hd)
    return k.swapaxes(1, 2), v.swapaxes(1, 2)


def forward_chunk(p: Params, tokens: jax.Array, rt: Runtime, table: jax.Array,
                  cache, pos: jax.Array, valid: Optional[jax.Array] = None,
                  frames: Optional[jax.Array] = None):
    """Positioned-chunk decoder forward: tokens [B, T] written at per-slot
    offsets pos [B] (scalar broadcasts); valid [B] masks a bucket-padded
    chunk.

    Self-attention scatters T K/V rows at each row's own offset and
    attends offset-causally.  Cross-attention K/V is static per request:
    when `frames` is given (the pos = 0 chunk of a fresh request) the
    source is encoded ONCE and its projected K/V replace the cross cache;
    later chunks and decode ticks reuse the cached xk/xv — the standard
    enc-dec serving optimization, now uniform across all chunk widths."""
    cfg = rt.cfg
    enc_out = encode(p, frames, rt) if frames is not None else None
    x = embed(p, tokens, rt)
    B, T = x.shape[:2]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None] + jnp.arange(T)[None, :]   # [B, T] per-row rope
    hd = cfg.head_dim_

    def body(carry, inp):
        x, table = carry
        layer_p, seg = inp
        h = norm(layer_p["norm1"], x, rt)
        a, new_kv = attention(layer_p, h, rt, positions,
                              cache={"k": seg["k"], "v": seg["v"]}, pos=pos)
        x = x + a
        with jax.named_scope("cross"):
            h = norm(layer_p["norm2"], x, rt)
            if enc_out is not None:
                xk, xv = _cross_kv(layer_p, enc_out, cfg)
                xk = xk.astype(seg["xk"].dtype)
                xv = xv.astype(seg["xv"].dtype)
            else:
                xk, xv = seg["xk"], seg["xv"]
            ap = layer_p["cross"]["attn"]
            q = linear(ap["wq"], h).reshape(B, T, cfg.n_heads, hd)
            if T == 1:
                src_len = jnp.full((B,), xk.shape[2], jnp.int32)
                o = ops.decode_attention(q[:, 0], xk, xv,
                                         kv_len=src_len, impl=rt.impl)
                o = o[:, None]                          # [B, 1, Hq, hd]
            else:
                o = ops.attention(q.swapaxes(1, 2), xk, xv, causal=False,
                                  impl=rt.impl).swapaxes(1, 2)
            x = x + linear(ap["wo"], o.reshape(B, T, cfg.n_heads * hd))
        h = norm(layer_p["norm3"], x, rt)
        x = x + mlp(layer_p, h, rt)
        new_seg = dict(seg)
        new_seg.update(new_kv)
        new_seg["xk"], new_seg["xv"] = xk, xv
        return (x, table), new_seg

    with scan_multiplier(cfg.dec_layers):
        (x, table), new_cache = jax.lax.scan(
            body, (x, table), (p["dec_stack"]["stack"], cache))
    x = norm(p["final_norm"], x, rt)
    logits = lm_head(p, last_valid(x, valid), rt)[:, 0]
    return logits, new_cache, table


def prefill(p: Params, tokens: jax.Array, rt: Runtime, table: jax.Array,
            cache, frames: Optional[jax.Array] = None):
    """Encode source + bulk-prefill the decoder prompt = forward_chunk at
    offset 0 with T = prompt length and the frames attached."""
    zero = jnp.zeros((tokens.shape[0],), jnp.int32)
    return forward_chunk(p, tokens, rt, table, cache, zero, frames=frames)


def decode_step(p: Params, token: jax.Array, rt: Runtime, table: jax.Array,
                cache, pos: jax.Array):
    """Pooled decode = forward_chunk at width T = 1.  token: [B]."""
    return forward_chunk(p, token[:, None], rt, table, cache, pos)


def declare_fold_slots(spec: DeviceFoldSpec, cfg: ModelConfig) -> None:
    spec.declare("app", "loss", "train_step", "count")
