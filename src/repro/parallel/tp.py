"""Manual Megatron-style tensor-parallel linear pairs (shard_map).

WHY (EXPERIMENTS.md §Perf, granite multi-pod): under plain pjit, the
backward dx of every TP linear is an all-reduce of the F32-ACCUMULATED
transpose-dot output — GSPMD places the AR before the bf16 downcast, and
emits one AR per projection. 10.9 TB/step on granite-20b train (2x16x16).

These layers take control of exactly those collectives:

  col_row_mlp:   up/gate column-parallel (no fwd comm) -> local activation
                 -> down row-parallel (ONE fwd psum, bf16). Backward: dx of
                 the whole block is ONE bf16 psum (the up/gate dx partials
                 are summed LOCALLY before reducing); dw stay local partials
                 reduced over the batch axes in f32 (numerics preserved
                 where it matters — weight grads).

Forward/backward numerics vs the pjit path: identical contraction order in
f32 accumulation; only the dx cotangent crossing the block boundary is
rounded to bf16 (standard mixed-precision practice). Equivalence-tested in
tests/test_tp_linear.py; enabled per-model with ModelConfig.manual_tp.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.axes import get_rules, get_runtime_mesh
from repro.parallel.compat import shard_map


def _axes(mesh: Mesh) -> Tuple[Tuple[str, ...], Optional[str]]:
    rules = get_rules()
    batch = tuple(a for a in rules.get("batch", ("pod", "data"))
                  if a in mesh.axis_names)
    model = next((a for a in rules.get("model", ("model",))
                  if a in mesh.axis_names), None)
    return batch, model


def manual_tp_available(d_ff: int) -> bool:
    mesh = get_runtime_mesh()
    if mesh is None:
        return False
    batch, model = _axes(mesh)
    if model is None:
        return False
    msize = dict(zip(mesh.axis_names, mesh.devices.shape))[model]
    return msize > 1 and d_ff % msize == 0


def col_row_mlp(x: jax.Array, w_up: jax.Array, w_down: jax.Array,
                w_gate: Optional[jax.Array], gated: bool) -> jax.Array:
    """x: [B, S, d] (batch-sharded, feature-replicated); w_up/w_gate:
    [d, f] column-sharded; w_down: [f, d] row-sharded. Returns [B, S, d]."""
    mesh = get_runtime_mesh()
    batch, model = _axes(mesh)
    bspec = P(batch, None, None)
    ws_in = (P(None, model), P(model, None)) + \
        ((P(None, model),) if gated else ())

    def body(x_l, w_up_l, w_down_l, *maybe_gate):
        return _mlp_core(x_l, w_up_l, w_down_l,
                         maybe_gate[0] if maybe_gate else None,
                         gated, model, batch)

    fn = shard_map(body, mesh=mesh,
                       in_specs=(bspec,) + ws_in, out_specs=bspec,
                       check_vma=False)
    args = (x, w_up, w_down) + ((w_gate,) if gated else ())
    return fn(*args)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _mlp_core(x_l, w_up_l, w_down_l, w_gate_l, gated, model_axis,
              batch_axes):
    y, _ = _mlp_fwd(x_l, w_up_l, w_down_l, w_gate_l, gated, model_axis,
                    batch_axes)
    return y


def _act(h_up, h_gate, gated):
    if gated:
        return (jax.nn.silu(h_gate.astype(jnp.float32))
                * h_up.astype(jnp.float32)).astype(h_up.dtype)
    return jax.nn.gelu(h_up.astype(jnp.float32)).astype(h_up.dtype)


def _mlp_fwd(x_l, w_up_l, w_down_l, w_gate_l, gated, model_axis,
             batch_axes):
    h_up = jnp.einsum("bsd,df->bsf", x_l, w_up_l.astype(x_l.dtype))
    h_gate = (jnp.einsum("bsd,df->bsf", x_l, w_gate_l.astype(x_l.dtype))
              if gated else None)
    h = _act(h_up, h_gate, gated)
    y_part = jnp.einsum("bsf,fd->bsd", h, w_down_l.astype(x_l.dtype))
    with jax.named_scope("mlp_fwd_psum"):
        y = jax.lax.psum(y_part, model_axis)      # ONE bf16 psum forward
    return y, (x_l, w_up_l, w_down_l, w_gate_l, h_up, h_gate)


def _psum_batch(v, batch_axes):
    for ax in batch_axes:
        v = jax.lax.psum(v, ax)
    return v


def _mlp_bwd(gated, model_axis, batch_axes, res, dy):
    x_l, w_up_l, w_down_l, w_gate_l, h_up, h_gate = res
    dy = dy.astype(x_l.dtype)                     # bf16 cotangent
    h = _act(h_up, h_gate, gated)
    # dw: f32 accumulation + explicit psum over the batch axes (check_vma is
    # off, so replicated-input cotangents must be reduced by hand)
    dw_down = _psum_batch(
        jnp.einsum("bsf,bsd->fd", h, dy,
                   preferred_element_type=jnp.float32), batch_axes)
    dh = jnp.einsum("bsd,fd->bsf", dy, w_down_l.astype(dy.dtype))
    # activation backward in f32
    dhf = dh.astype(jnp.float32)
    if gated:
        sg = jax.nn.sigmoid(h_gate.astype(jnp.float32))
        silu = h_gate.astype(jnp.float32) * sg
        d_up = (dhf * silu)
        d_gate = dhf * h_up.astype(jnp.float32) * sg \
            * (1 + h_gate.astype(jnp.float32) * (1 - sg))
    else:
        _, gelu_vjp = jax.vjp(
            lambda t: jax.nn.gelu(t.astype(jnp.float32)), h_up)
        (d_up,) = gelu_vjp(dhf)
        d_up = d_up.astype(jnp.float32)
        d_gate = None
    d_up = d_up.astype(x_l.dtype)
    dw_up = _psum_batch(
        jnp.einsum("bsd,bsf->df", x_l, d_up,
                   preferred_element_type=jnp.float32), batch_axes)
    dx_part = jnp.einsum("bsf,df->bsd", d_up, w_up_l.astype(x_l.dtype))
    dw_gate = None
    if gated:
        d_gate = d_gate.astype(x_l.dtype)
        dw_gate = _psum_batch(
            jnp.einsum("bsd,bsf->df", x_l, d_gate,
                       preferred_element_type=jnp.float32), batch_axes)
        # sum the up/gate dx partials LOCALLY before the single psum
        dx_part = dx_part + jnp.einsum("bsf,df->bsd", d_gate,
                                       w_gate_l.astype(x_l.dtype))
    with jax.named_scope("mlp_bwd_psum"):
        dx = jax.lax.psum(dx_part, model_axis)    # ONE bf16 psum backward
    dw_up = dw_up.astype(w_up_l.dtype)
    dw_down = dw_down.astype(w_down_l.dtype)
    if dw_gate is not None:
        dw_gate = dw_gate.astype(w_gate_l.dtype)
    return dx, dw_up, dw_down, dw_gate


_mlp_core.defvjp(_mlp_fwd, _mlp_bwd)
