"""Fused RMSNorm — Pallas TPU kernel.

Fuses the square-reduce, rsqrt, scale and (optional) residual-add into one
VMEM pass over [BR, D] row blocks: 1 HBM read + 1 write instead of the 3-4
passes an unfused chain costs (norm is memory-bound; the fusion matters for
the memory roofline term). Reduction runs in f32 regardless of io dtype.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import tpu_compiler_params


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                    # [BR, D]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _rmsnorm_add_kernel(x_ref, r_ref, w_ref, o_ref, res_ref, *, eps: float):
    s = (x_ref[...].astype(jnp.float32)
         + r_ref[...].astype(jnp.float32))                # fused residual add
    res_ref[...] = s.astype(res_ref.dtype)
    var = jnp.mean(s * s, axis=-1, keepdims=True)
    y = s * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: [..., D]; w: [D]."""
    orig_shape = x.shape
    D = orig_shape[-1]
    x2 = x.reshape(-1, D)
    R = x2.shape[0]
    br = min(block_rows, R)
    # pad rows to a multiple of the block
    pad = (-R) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    nr = x2.shape[0] // br

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="xfa_rmsnorm",
    )(x2, w)
    if pad:
        out = out[:R]
    return out.reshape(orig_shape)


def rmsnorm_add(x: jax.Array, residual: jax.Array, w: jax.Array, *,
                eps: float = 1e-5, block_rows: int = 256,
                interpret: bool = False):
    """Fused (x + residual) -> (rmsnorm(sum), sum). Saves one HBM round-trip
    in the pre-norm transformer block pattern."""
    orig_shape = x.shape
    D = orig_shape[-1]
    x2 = x.reshape(-1, D)
    r2 = residual.reshape(-1, D)
    R = x2.shape[0]
    br = min(block_rows, R)
    pad = (-R) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        r2 = jnp.pad(r2, ((0, pad), (0, 0)))
    nr = x2.shape[0] // br

    y, s = pl.pallas_call(
        functools.partial(_rmsnorm_add_kernel, eps=eps),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((br, D), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2.shape, x.dtype),
            jax.ShapeDtypeStruct(x2.shape, x.dtype),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="xfa_rmsnorm_add",
    )(x2, r2, w)
    if pad:
        y, s = y[:R], s[:R]
    return y.reshape(orig_shape), s.reshape(orig_shape)
