"""Deterministic synthetic workload -> reference XFA profile.

Folds a fixed, seeded event stream shaped like a smoke training run (data
loading, dispatch, device sync, checkpoint writes, optimizer work, a few
wait edges) plus device-layer style metric emissions, and persists it as
an uncompressed snapshot.  Every byte is a function of (seed, steps,
scale): rerunning the script reproduces the checked-in baseline exactly.

CI (non-blocking `profile-diff` lane) regenerates the candidate profile
and runs

    python -m repro.profile diff tests/data/ci_baseline.xfa.npz cand.xfa.npz

so the whole persist -> reduce -> diff pipeline is exercised as a perf
gate on every push; `--scale`/`--extra-edge` exist to inject regressions
when calibrating thresholds (ROADMAP: thresholds logged, not yet gating).

Regenerate the checked-in baseline after a DELIBERATE profile-shape change:

    python benchmarks/baseline_profile.py -o tests/data/ci_baseline.xfa.npz

`--thresholds-out` additionally fits per-edge noise bands across `--runs`
seeds of the same workload (seed, seed+1, ...) via repro.analysis.calibrate
— the measured-variance replacement for the hand-picked `--threshold`:

    python benchmarks/baseline_profile.py -o /dev/null \
        --runs 8 --thresholds-out tests/data/ci_thresholds.json
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.folding import EdgeStats, FoldedTable  # noqa: E402
from repro.profile import ProfileSnapshot  # noqa: E402

#: the synthetic run's cross-flow edges: (caller, component, api,
#: mean_ns, wait?) — roughly the shape a smoke train run folds.
EDGES = (
    ("app", "data", "next_batch", 120_000, False),
    ("app", "data", "generate_batch", 450_000, False),
    ("app", "runtime", "dispatch_step", 2_500_000, False),
    ("runtime", "runtime", "device_sync", 1_200_000, True),
    ("app", "runtime", "compile_step", 30_000_000, False),
    ("app", "ckpt", "save", 5_000_000, False),
    ("ckpt", "runtime", "flush_wait", 800_000, True),
    ("app", "optimizer", "apply_updates", 900_000, False),
    ("optimizer", "collective", "grad_allreduce", 600_000, False),
    ("app", "loss", "train_step", 0, False),
)


def build_profile(steps: int = 50, seed: int = 0,
                  scale: float = 1.0) -> FoldedTable:
    rng = np.random.default_rng(seed)
    t = FoldedTable(group="ci-baseline")
    for caller, comp, api, mean_ns, wait in EDGES:
        count = steps                         # every edge fires per step
        if api == "compile_step":
            count = 1
        elif api == "save":
            count = max(steps // 10, 1)
        elif api == "flush_wait":
            count = max(steps // 10, 1)
        if mean_ns == 0:                      # count-only edge
            t.edges[(caller, comp, api)] = EdgeStats(count=count)
            continue
        # deterministic "timings": seeded integer jitter around the mean
        durs = (mean_ns + rng.integers(-mean_ns // 10, mean_ns // 10,
                                       size=count)) * scale
        durs = durs.astype(np.int64)
        t.edges[(caller, comp, api)] = EdgeStats(
            count=count, total_ns=int(durs.sum()),
            child_ns=int(durs.sum() // 20),
            min_ns=int(durs.min()), max_ns=int(durs.max()),
            kind=1 if wait else 0)
    # device-layer style metrics (flops/bytes), metric-mask exercised
    t.edges[("app", "runtime", "dispatch_step")].metrics = {
        "flops": float(steps) * 1.0e12, "bytes": float(steps) * 2.0e9}
    t.edges[("app", "loss", "train_step")].metrics = {"tokens": 0.0}
    return t


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("-o", "--output", default="baseline.xfa.npz")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="multiply all durations (inject a regression)")
    ap.add_argument("--extra-edge", action="store_true",
                    help="add a new hot edge (exercise flag_added)")
    ap.add_argument("--thresholds-out", default="",
                    help="also fit per-edge noise bands across --runs "
                         "seeds and write them as a thresholds json")
    ap.add_argument("--runs", type=int, default=8,
                    help="seeds sampled for --thresholds-out calibration")
    args = ap.parse_args()

    if args.thresholds_out:
        from repro.analysis import calibrate_runs
        samples = [build_profile(args.steps, args.seed + i, args.scale)
                   for i in range(args.runs)]
        thr = calibrate_runs(
            samples,
            meta={"workload": "benchmarks/baseline_profile.py",
                  "steps": args.steps, "seeds": [args.seed + i
                                                 for i in range(args.runs)],
                  "scale": args.scale})
        thr.save(args.thresholds_out)
        print(f"wrote {args.thresholds_out}: {len(thr)} edge bands "
              f"from {args.runs} seeded runs")

    t = build_profile(args.steps, args.seed, args.scale)
    if args.extra_edge:
        t.edges[("app", "moe", "unexpected_dispatch")] = EdgeStats(
            count=args.steps, total_ns=10_000_000 * args.steps,
            min_ns=9_000_000, max_ns=11_000_000)
    snap = ProfileSnapshot.from_folded(
        t, meta={"label": "ci-baseline", "steps": args.steps,
                 "seed": args.seed, "scale": args.scale})
    snap.save(args.output, compress=False)
    print(f"wrote {args.output}: {len(t)} edges, "
          f"{t.total_ns()/1e9:.3f}s folded")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
