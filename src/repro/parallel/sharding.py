"""Parameter/state sharding rules: path-pattern -> logical axes -> PartitionSpec.

Every parameter leaf is matched by exactly one rule (tests enforce this).
Scanned stacks carry a leading layer axis -> None is prepended automatically
(detected via the '/stack' marker the model builders put in the path).

The rules implement Megatron-style TP over 'model', batch DP over
('pod','data'), EP over 'model' for experts, plus optional FSDP (params over
'data') and ZeRO-1 (optimizer state over 'data') applied as *transforms* on
top of the base spec — so the paper-faithful baseline and the optimized
variants share one rule table and differ only in declared transforms.
"""

from __future__ import annotations

import re
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .axes import get_rules

# (regex over 'a/b/c' param path, logical axes per trailing dim of the leaf)
# Leading scan axis handled separately. Order matters: first match wins.
RULES: List[Tuple[str, Tuple[Optional[str], ...]]] = [
    # embeddings / unembedding: vocab sharded over model axis
    (r".*/embed/table$", ("vocab", None)),
    (r".*/lm_head/w$", (None, "vocab")),
    # MLA projections (deepseek)
    (r".*/attn/wq$", (None, "model")),
    (r".*/attn/wkv_a$", (None, None)),
    (r".*/attn/wkv_b$", (None, "model")),
    # attention
    (r".*/attn/w[kv]$", (None, "model")),
    (r".*/attn/wo$", ("model", None)),
    (r".*/attn/(q_norm|k_norm)$", (None,)),
    # MoE expert stacks: EP over the model axis; d_ff per expert unsharded
    # (the expert dim and d_ff cannot both map to 'model')
    (r".*/moe/(w_gate|w_up)$", ("expert", None, None)),
    (r".*/moe/w_down$", ("expert", None, None)),
    (r".*/moe/router$", (None, None)),
    (r".*/moe/shared/(w_gate|w_up)$", (None, "model")),
    (r".*/moe/shared/w_down$", ("model", None)),
    # dense MLP
    (r".*/mlp/(w_gate|w_up)$", (None, "model")),
    (r".*/mlp/w_down$", ("model", None)),
    # mamba2
    (r".*/ssm/in_proj$", (None, "model")),
    (r".*/ssm/out_proj$", ("model", None)),
    (r".*/ssm/conv_w$", (None, "model")),
    (r".*/ssm/(a_log|dt_bias|d_skip)$", ("model",)),
    (r".*/ssm/norm$", ("model",)),
    # xlstm
    (r".*/mlstm/w_up$", (None, "model")),
    (r".*/mlstm/w_(q|k|v)$", ("model", None)),
    (r".*/mlstm/w_gates$", (None, None)),
    (r".*/mlstm/w_down$", ("model", None)),
    (r".*/mlstm/skip$", ("model",)),
    (r".*/slstm/w_(i|f|z|o)$", (None, "model")),
    (r".*/slstm/r_(i|f|z|o)$", ("model", None)),
    (r".*/slstm/(ffn_gate|ffn_up)$", (None, "model")),
    (r".*/slstm/ffn_down$", ("model", None)),
    # norms and other vectors/scalars: replicated
    (r".*/[\w]*norm[\w]*/scale$", (None,)),
    (r".*/bias$", (None,)),
    # frontend stubs project precomputed embeddings into d_model
    (r".*/frontend/w$", (None, "model")),
]

_COMPILED = [(re.compile(pat), axes) for pat, axes in RULES]


def logical_axes_for(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    stacked = "/stack/" in path
    base = path.replace("/stack/", "/")
    for rx, axes in _COMPILED:
        if rx.match(base):
            out: Tuple[Optional[str], ...] = axes
            if stacked:
                out = (None,) + tuple(axes)
            if len(out) < ndim:   # broadcast leading None (extra stack dims)
                out = (None,) * (ndim - len(out)) + tuple(out)
            if len(out) != ndim:
                raise ValueError(
                    f"rule for {path} gives {len(out)} axes, leaf has {ndim}")
            return out
    raise KeyError(f"no sharding rule matches param path: {path}")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/" + "/".join(parts)


def _to_mesh_axes(logical: Tuple[Optional[str], ...], mesh: Optional[Mesh],
                  shape: Optional[Sequence[int]] = None) -> P:
    """Translate logical axes to mesh axes, dropping any that do not EVENLY
    divide the dim (pjit argument shardings require divisibility; vocab
    151655 or d_ff 2730 fall back to replicated)."""
    rules = get_rules()
    mesh_axes = set(mesh.axis_names) if mesh is not None else set()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}
    parts = []
    for i, ax in enumerate(logical):
        if ax is None:
            parts.append(None)
            continue
        mapped = tuple(m for m in rules.get(ax, (ax,)) if m in mesh_axes)
        if mapped and shape is not None:
            extent = 1
            for m in mapped:
                extent *= sizes.get(m, 1)
            if extent == 0 or shape[i] % extent != 0:
                mapped = ()
        parts.append(None if not mapped else
                     (mapped[0] if len(mapped) == 1 else mapped))
    return P(*parts)


def spec_tree(params: Any, mesh: Optional[Mesh],
              fsdp: bool = False) -> Any:
    """PartitionSpec pytree matching `params` (arrays or ShapeDtypeStructs).

    fsdp=True additionally shards the largest still-replicated dim over
    'data' when divisible — the ZeRO-3-style transform used in perf variants.
    """
    dsize = 1
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dsize = sizes.get("data", 1)

    def leaf_spec(path, leaf):
        pstr = _path_str(path)
        logical = logical_axes_for(pstr, len(leaf.shape))
        spec = _to_mesh_axes(logical, mesh, leaf.shape)
        if fsdp and mesh is not None and dsize > 1:
            spec = _apply_fsdp(spec, leaf.shape, dsize)
        return spec

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def _apply_fsdp(spec: P, shape: Sequence[int], dsize: int) -> P:
    parts = list(spec) + [None] * (len(shape) - len(spec))
    # pick the largest dim not already sharded, divisible by data size
    best, best_dim = -1, -1
    for i, (p, s) in enumerate(zip(parts, shape)):
        if p is None and s % dsize == 0 and s > best:
            best, best_dim = s, i
    if best_dim >= 0:
        parts[best_dim] = "data"
    return P(*parts)


def sharding_tree(params: Any, mesh: Optional[Mesh], fsdp: bool = False):
    specs = spec_tree(params, mesh, fsdp)
    if mesh is None:
        return specs
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def validate_rules(params: Any) -> List[str]:
    """Return list of param paths with no matching rule (tests assert [])."""
    bad = []

    def check(path, leaf):
        p = _path_str(path)
        try:
            logical_axes_for(p, len(leaf.shape))
        except KeyError:
            bad.append(p)
        return leaf

    jax.tree_util.tree_map_with_path(check, params)
    return bad
