"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, inherently sequential).

mLSTM cell per head:   C_t = f_t C_{t-1} + i_t v_t k_t^T      (matrix memory)
                       n_t = f_t n_{t-1} + i_t k_t            (normalizer)
                       h_t = (C_t q_t) / max(|n_t . q_t|, 1)
with f_t = sigmoid(f̃) and i_t = exp(ĩ) stabilized by the running max
m_t = max(log f_t + m_{t-1}, ĩ_t): effective gates carry exp(·−m_t).

Training runs the CHUNKWISE-parallel form (quadratic within chunks, O(1)
state across chunks — the same blocking as the Mamba2 SSD kernel, plus
normalizer + stabilizer carries), validated against the sequential oracle in
tests. Decode is the O(1) per-token recurrence.

sLSTM is sequential by construction (hidden-to-hidden recurrence, block-
diagonal per head) — lax.scan over time; its FLOPs are tiny (d^2 per token).

Block layout (xLSTM[7:1]-style): super-blocks of (slstm_every-1 mLSTM +
1 sLSTM), scanned; d_ff=0 — projection factors live inside the blocks.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.device_fold import DeviceFoldSpec, annotate_cost, scan_multiplier
from repro.kernels import ops
from repro.parallel.axes import shard

from .layers import (Params, Runtime, _init, cross_entropy, embed,
                     init_embed, init_lm_head, init_norm, last_valid,
                     lm_head, linear, norm, pdtype)


# --------------------------------------------------------------- mLSTM ----
def init_mlstm_block(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = int(d * cfg.mlstm_proj_factor)
    h = cfg.n_heads
    ph = di // h
    ks = jax.random.split(key, 6)
    dt = pdtype(cfg)
    p = {
        "w_up": _init(ks[0], (d, 2 * di), dt),
        "w_q": _init(ks[1], (h, ph, ph), dt, scale=ph ** -0.5),
        "w_k": _init(ks[2], (h, ph, ph), dt, scale=ph ** -0.5),
        "w_v": _init(ks[3], (h, ph, ph), dt, scale=ph ** -0.5),
        "w_gates": _init(ks[4], (d, 2 * h), dt, scale=d ** -0.5),
        "w_down": _init(ks[5], (di, d), dt),
        "skip": jnp.ones((di,), dt),
    }
    return {"norm1": init_norm(cfg), "mlstm": p}


def _mlstm_cell_seq(q, k, v, logf, logi):
    """Sequential stabilized oracle. q/k/v: [B,H,L,ph]; logf/logi: [B,H,L].
    Returns (y [B,H,L,ph], state (C, n, m))."""
    B, H, L, ph = q.shape
    C0 = jnp.zeros((B, H, ph, ph), jnp.float32)
    n0 = jnp.zeros((B, H, ph), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)

    def step(carry, inp):
        C, n, m = carry
        q_t, k_t, v_t, lf, li = inp
        m_new = jnp.maximum(lf + m, li)
        f_eff = jnp.exp(lf + m - m_new)
        i_eff = jnp.exp(li - m_new)
        C = f_eff[..., None, None] * C \
            + i_eff[..., None, None] * (v_t[..., :, None] * k_t[..., None, :])
        n = f_eff[..., None] * n + i_eff[..., None] * k_t
        num = jnp.einsum("bhvk,bhk->bhv", C, q_t)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t))
        den = jnp.maximum(den, jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 2, 0)
               for a in (q, k, v, logf, logi))
    (C, n, m), ys = jax.lax.scan(step, (C0, n0, m0), xs)
    return jnp.moveaxis(ys, 0, 2), (C, n, m)


def _mlstm_cell_chunked(q, k, v, logf, logi, chunk: int,
                        state=None, constrain: bool = False):
    """Chunkwise-parallel stabilized mLSTM. Shapes as _mlstm_cell_seq.

    Per chunk (length T): with cum = inclusive cumsum(logf),
      intra:  w[t,s] = exp(cum[t]-cum[s]+li[s] - m_t)·(q_t.k_s), s<=t
      inter:  C contribution exp(cum[t]+m_prev - m_t)·(C_prev q_t)
      m_t   = max(m_prev + cum[t], runmax_t(li - cum_exclusive))  (stabilizer)
    Carries (C, n, m) across chunks.
    """
    B, H, L, ph = q.shape
    pad = (-L) % chunk
    if pad:
        zp = lambda a: jnp.pad(a, [(0, 0), (0, 0), (0, pad)]
                               + [(0, 0)] * (a.ndim - 3))
        q, k, v = zp(q), zp(k), zp(v)
        logf = jnp.pad(logf, [(0, 0), (0, 0), (0, pad)])
        logi = jnp.pad(logi, [(0, 0), (0, 0), (0, pad)],
                       constant_values=-1e30)
    Lp = L + pad
    nc = Lp // chunk
    # pin scan operands/carries: H=4 cannot shard over model=16, so shard
    # the head-feature dim instead — unconstrained carries replicate and
    # re-gather q/k/v per chunk (measured 503 GB/step on xlstm prefill_32k,
    # EXPERIMENTS.md §Perf)
    from repro.parallel.axes import shard_dims
    # feature-sharded (ph over model): costs a small per-chunk score psum
    # (~9 MB) but beats both alternatives MEASURED on xlstm prefill_32k:
    # unconstrained carries -> 503 GB/step of per-chunk re-gathers; batch-
    # only replication -> 823 GB/step of projection-output all-gathers.
    # TRAIN is the opposite (the bwd chunk scan pays extra dC psums:
    # 19.3 -> 31.7 s measured) so constraints apply to the serving paths
    # only (EXPERIMENTS.md §Perf xlstm iterations 1-4)
    if constrain:
        _cb = lambda t: shard_dims(t, {0: "batch"})
        _cf = lambda t: shard_dims(t, {0: "batch", t.ndim - 1: "model"})
    else:
        _cb = _cf = lambda t: t
    rs = lambda a: a.reshape(B, H, nc, chunk, *a.shape[3:])
    qc, kc, vc = (_cf(rs(a.astype(jnp.float32))) for a in (q, k, v))
    lfc, lic = rs(logf.astype(jnp.float32)), rs(logi.astype(jnp.float32))

    cum = jnp.cumsum(lfc, axis=3)                          # inclusive [...,T]
    # stabilizer basis: m_t = cum_t + max(m_prev, runmax_t(li_s - cum_s))
    u = lic - cum                                          # [B,H,nc,T]
    runmax_u = jax.lax.associative_scan(jnp.maximum, u, axis=3)
    csum = cum[..., -1]                                    # chunk log-decay

    if state is None:
        C0 = jnp.zeros((B, H, ph, ph), jnp.float32)
        n0 = jnp.zeros((B, H, ph), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def chunk_step(carry, inp):
        C, n, m = carry
        C, n, m = _cf(C), _cf(n), _cb(m)
        q_k, k_k, v_k, cum_k, u_k, rmu_k, li_k, cs_k = inp
        q_k, k_k, v_k = _cf(q_k), _cf(k_k), _cf(v_k)
        # m_t = cum_t + max(m_prev, runmax(li - cum)_t)  [B,H,T]
        m_t = cum_k + jnp.maximum(m[..., None], rmu_k)
        # intra-chunk weights: exp(cum_t - cum_s + li_s - m_t) causal
        T = q_k.shape[2]
        a = cum_k[..., :, None] + (li_k - cum_k)[..., None, :]  # [B,H,T,T]
        w = jnp.exp(a - m_t[..., :, None])
        tri = jnp.tril(jnp.ones((T, T), bool))
        w = jnp.where(tri[None, None], w, 0.0)
        scores = jnp.einsum("bhtd,bhsd->bhts", q_k, k_k)
        num = jnp.einsum("bhts,bhts,bhsd->bhtd", scores, w, v_k)
        den_l = jnp.einsum("bhts,bhts->bht", scores, w)
        # inter-chunk
        dec_t = jnp.exp(cum_k + m[..., None] - m_t)        # [B,H,T]
        num = num + dec_t[..., None] * jnp.einsum("bhvk,bhtk->bhtv", C, q_k)
        den_i = dec_t * jnp.einsum("bhk,bhtk->bht", n, q_k)
        den = jnp.abs(den_l + den_i)
        den = jnp.maximum(den, jnp.exp(-m_t))
        y = num / den[..., None]
        # carry update at chunk end
        m_end = m_t[..., -1]
        w_in = jnp.exp(cum_k[..., -1:] - cum_k + li_k - m_end[..., None])
        C = jnp.exp(cs_k + m - m_end)[..., None, None] * C \
            + jnp.einsum("bht,bhtv,bhtk->bhvk", w_in, v_k, k_k)
        n = jnp.exp(cs_k + m - m_end)[..., None] * n \
            + jnp.einsum("bht,bhtk->bhk", w_in, k_k)
        return (_cf(C), _cf(n), _cb(m_end)), y

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in
               (qc, kc, vc, cum, u, runmax_u, lic, csum))
    (C, n, m), ys = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 2).reshape(B, H, Lp, ph)
    if pad:
        y = y[:, :, :L]
    return y, (C, n, m)


def _mlstm_cell_step(q, k, v, logf, logi, state):
    """Single-token decode. q/k/v: [B,H,ph]; logf/logi: [B,H]."""
    C, n, m = state
    m_new = jnp.maximum(logf + m, logi)
    f_eff = jnp.exp(logf + m - m_new)
    i_eff = jnp.exp(logi - m_new)
    C = f_eff[..., None, None] * C \
        + i_eff[..., None, None] * (v[..., :, None] * k[..., None, :])
    n = f_eff[..., None] * n + i_eff[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)),
                      jnp.exp(-m_new))
    return num / den[..., None], (C, n, m_new)


def mlstm_block(p: Params, x: jax.Array, rt: Runtime,
                state=None, return_state: bool = False,
                valid: Optional[jax.Array] = None):
    """x: [B, L, d] -> (y, new_state).

    valid: [B] real-token counts of a bucket-padded chunk — pad steps get
    log f = 0 (no decay) and log i = -inf (no injection), so (C, n, m)
    pass through them untouched: the SAME trick the chunked cell already
    uses for its internal chunk-multiple padding."""
    cfg = rt.cfg
    mp = p["mlstm"]
    B, L, d = x.shape
    di = int(d * cfg.mlstm_proj_factor)
    H = cfg.n_heads
    ph = di // H
    with jax.named_scope("mlstm"):
        h = norm(p["norm1"], x, rt)
        up = linear(mp["w_up"], h)
        xin, z = up[..., :di], up[..., di:]
        gates = linear(mp["w_gates"], h).astype(jnp.float32)  # [B,L,2H]
        logf = jax.nn.log_sigmoid(gates[..., :H]).swapaxes(1, 2)  # [B,H,L]
        logi = gates[..., H:].swapaxes(1, 2)
        if valid is not None:
            real = jnp.arange(L)[None, None, :] \
                < jnp.asarray(valid, jnp.int32)[:, None, None]
            logf = jnp.where(real, logf, 0.0)
            logi = jnp.where(real, logi, -1e30)
        xh = xin.reshape(B, L, H, ph).transpose(0, 2, 1, 3)   # [B,H,L,ph]
        q = jnp.einsum("bhld,hde->bhle", xh, mp["w_q"].astype(xh.dtype))
        k = jnp.einsum("bhld,hde->bhle", xh, mp["w_k"].astype(xh.dtype)) \
            * ph ** -0.5
        v = jnp.einsum("bhld,hde->bhle", xh, mp["w_v"].astype(xh.dtype))
        annotate_cost("mlstm", "mlstm", "proj",
                      flops=2.0 * B * L * (d * 2 * di + 3 * di * ph
                                           + d * 2 * H + di * d))
        if state is None or L > 1:
            y, new_state = _mlstm_cell_chunked(
                q, k, v, logf, logi, chunk=min(cfg.ssm_chunk, max(L, 1)),
                state=state,
                constrain=(state is not None or return_state))
        else:
            y, new_state = _mlstm_cell_step(
                q[:, :, 0], k[:, :, 0], v[:, :, 0], logf[:, :, 0],
                logi[:, :, 0], state)
            y = y[:, :, None]
        y = y.transpose(0, 2, 1, 3).reshape(B, L, di).astype(x.dtype)
        y = y + mp["skip"].astype(x.dtype) * xin
        y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
        out = linear(mp["w_down"], y)
        if not (return_state or state is not None):
            new_state = None
        return shard(out, "batch", "seq", None), new_state


def init_mlstm_state(cfg: ModelConfig, batch: int):
    di = int(cfg.d_model * cfg.mlstm_proj_factor)
    H = cfg.n_heads
    ph = di // H
    return (jnp.zeros((batch, H, ph, ph), jnp.float32),
            jnp.zeros((batch, H, ph), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32))


# --------------------------------------------------------------- sLSTM ----
def init_slstm_block(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    ph = d // H
    ks = jax.random.split(key, 11)
    dt = pdtype(cfg)
    p = {}
    for i, g in enumerate("ifzo"):
        p[f"w_{g}"] = _init(ks[i], (d, d), dt)
        p[f"r_{g}"] = _init(ks[4 + i], (H, ph, ph), dt, scale=ph ** -0.5)
    f_ffn = int(d * 4 / 3)
    p["ffn_gate"] = _init(ks[8], (d, f_ffn), dt)
    p["ffn_up"] = _init(ks[9], (d, f_ffn), dt)
    p["ffn_down"] = _init(ks[10], (f_ffn, d), dt)
    return {"norm1": init_norm(cfg), "norm2": init_norm(cfg), "slstm": p}


def _slstm_scan(sp: Params, x: jax.Array, cfg: ModelConfig, state,
                mask: Optional[jax.Array] = None):
    """x: [B, L, d]; sequential stabilized sLSTM. Returns (y, state).

    mask: [B, L] — True on real tokens of a bucket-padded chunk; at pad
    steps EVERY carry (c, n, m, h) passes through unchanged, so the state
    handed to the next chunk is the one after each row's last real token
    (the hidden-to-hidden recurrence means h itself is state here — gate
    tricks alone can't protect it)."""
    B, L, d = x.shape
    H = cfg.n_heads
    ph = d // H
    wi = jnp.stack([sp["w_i"], sp["w_f"], sp["w_z"], sp["w_o"]])  # [4,d,d]
    ri = jnp.stack([sp["r_i"], sp["r_f"], sp["r_z"], sp["r_o"]])  # [4,H,p,p]
    pre = jnp.einsum("bld,gde->bgle", x.astype(jnp.float32),
                     wi.astype(jnp.float32))                      # [B,4,L,d]

    def step(carry, t):
        c, n, m, hprev = carry
        hp = hprev.reshape(B, H, ph)
        rec = jnp.einsum("bhp,ghpe->bghe", hp, ri.astype(jnp.float32))
        gi = pre[:, :, t] + rec.reshape(B, 4, d)
        it, ft, zt, ot = gi[:, 0], gi[:, 1], gi[:, 2], gi[:, 3]
        lf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(lf + m, it)
        i_eff = jnp.exp(it - m_new)
        f_eff = jnp.exp(lf + m - m_new)
        c_new = f_eff * c + i_eff * jnp.tanh(zt)
        n_new = f_eff * n + i_eff
        hnew = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
        if mask is not None:
            mb = mask[:, t][:, None]
            c_new = jnp.where(mb, c_new, c)
            n_new = jnp.where(mb, n_new, n)
            m_new = jnp.where(mb, m_new, m)
            hnew = jnp.where(mb, hnew, hprev)
        return (c_new, n_new, m_new, hnew), hnew

    (c, n, m, hlast), ys = jax.lax.scan(step, state, jnp.arange(L))
    return jnp.moveaxis(ys, 0, 1), (c, n, m, hlast)


def slstm_block(p: Params, x: jax.Array, rt: Runtime,
                state=None, return_state: bool = False,
                valid: Optional[jax.Array] = None):
    cfg = rt.cfg
    sp = p["slstm"]
    B, L, d = x.shape
    with jax.named_scope("slstm"):
        h = norm(p["norm1"], x, rt)
        st = state if state is not None else init_slstm_state(cfg, B)
        mask = None if valid is None else (
            jnp.arange(L)[None, :] < jnp.asarray(valid, jnp.int32)[:, None])
        y, new_state = _slstm_scan(sp, h, cfg, st, mask=mask)
        annotate_cost("slstm", "slstm", "cell",
                      flops=2.0 * B * L * (4 * d * d + 4 * d * d
                                           / max(cfg.n_heads, 1)))
        x = x + y.astype(x.dtype)
        h2 = norm(p["norm2"], x, rt)
        g = jax.nn.silu(linear(sp["ffn_gate"], h2).astype(jnp.float32))
        u = linear(sp["ffn_up"], h2).astype(jnp.float32)
        x = x + linear(sp["ffn_down"], (g * u).astype(x.dtype))
        if not (return_state or state is not None):
            new_state = None
        return shard(x, "batch", "seq", None), new_state


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return (jnp.zeros((batch, d), jnp.float32),
            jnp.zeros((batch, d), jnp.float32),
            jnp.full((batch, d), -1e30, jnp.float32),
            jnp.zeros((batch, d), jnp.float32))


# ----------------------------------------------------------- full model ----
def init_params(key, cfg: ModelConfig) -> Params:
    assert cfg.slstm_every > 0
    n_super = cfg.n_layers // cfg.slstm_every
    n_m = cfg.slstm_every - 1
    ks = jax.random.split(key, 5)
    p: Dict[str, Any] = {}
    p.update(init_embed(ks[0], cfg))
    p.update(init_lm_head(ks[1], cfg))
    p["final_norm"] = init_norm(cfg)
    mkeys = jax.random.split(ks[2], n_super * n_m).reshape(n_super, n_m)
    skeys = jax.random.split(ks[3], n_super)
    p["stack_mlstm"] = {"stack": jax.vmap(jax.vmap(
        functools.partial(init_mlstm_block, cfg=cfg)))(mkeys)}
    p["stack_slstm"] = {"stack": jax.vmap(
        functools.partial(init_slstm_block, cfg=cfg))(skeys)}
    return p


def forward(p: Params, tokens: jax.Array, rt: Runtime, table: jax.Array,
            prefix_embeds=None):
    cfg = rt.cfg
    n_super = cfg.n_layers // cfg.slstm_every
    n_m = cfg.slstm_every - 1
    x = embed(p, tokens, rt)

    def super_body(carry, inp):
        x, table = carry
        m_stack, s_p = inp

        def inner(c2, layer_p):
            x2, = c2
            y, _ = mlstm_block(layer_p, x2, rt)
            return (x2 + y,), None

        with scan_multiplier(n_m):
            (x,), _ = jax.lax.scan(inner, (x,), m_stack)
        x, _ = slstm_block(s_p, x, rt)
        return (x, table), None

    if cfg.remat != "none":
        super_body = jax.checkpoint(
            super_body, policy=jax.checkpoint_policies.dots_saveable
            if cfg.remat == "dots_saveable" else None)
    with scan_multiplier(n_super):
        (x, table), _ = jax.lax.scan(
            super_body, (x, table),
            (p["stack_mlstm"]["stack"], p["stack_slstm"]["stack"]))
    x = norm(p["final_norm"], x, rt)
    return x, table, jnp.float32(0.0)


def loss_fn(p: Params, batch, rt: Runtime, table: jax.Array):
    x, table, aux = forward(p, batch["tokens"], rt, table)
    logits = lm_head(p, x, rt)
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss + aux, ({"loss": loss, "aux_loss": aux}, table)


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0, dtype=None):
    """xLSTM state is O(1) in sequence length — max_len is ignored."""
    n_super = cfg.n_layers // cfg.slstm_every
    n_m = cfg.slstm_every - 1
    stackm = lambda leaves: jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_super, n_m) + a.shape), leaves)
    stacks = lambda leaves: jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_super,) + a.shape), leaves)
    return {"mlstm": stackm(init_mlstm_state(cfg, batch)),
            "slstm": stacks(init_slstm_state(cfg, batch))}


def _run_with_state(p, x, rt, cache, table,
                    valid: Optional[jax.Array] = None):
    cfg = rt.cfg
    n_super = cfg.n_layers // cfg.slstm_every
    n_m = cfg.slstm_every - 1

    def super_body(carry, inp):
        x, table = carry
        m_stack, s_p, m_state, s_state = inp

        def inner(c2, inp2):
            x2, = c2
            layer_p, st = inp2
            y, new_st = mlstm_block(layer_p, x2, rt, state=st, valid=valid)
            return (x2 + y,), new_st

        with scan_multiplier(n_m):
            (x,), new_m = jax.lax.scan(inner, (x,), (m_stack, m_state))
        x, new_s = slstm_block(s_p, x, rt, state=s_state, valid=valid)
        return (x, table), (new_m, new_s)

    with scan_multiplier(n_super):
        (x, table), (new_m, new_s) = jax.lax.scan(
            super_body, (x, table),
            (p["stack_mlstm"]["stack"], p["stack_slstm"]["stack"],
             cache["mlstm"], cache["slstm"]))
    return x, table, {"mlstm": new_m, "slstm": new_s}


def forward_chunk(p: Params, tokens: jax.Array, rt: Runtime, table: jax.Array,
                  cache, pos: jax.Array, valid: Optional[jax.Array] = None):
    """Positioned-chunk forward: tokens [B, T] continue each row's
    recurrent state; pos [B] is accepted for API uniformity — xLSTM state
    is recurrent and position-free, and every state update is
    row-independent, so mixed-depth slots need no masking beyond the
    bucket-pad `valid` counts.  T = 1 is the pooled decode recurrence;
    a fresh cache with T = prompt length is bulk prefill."""
    x = embed(p, tokens, rt)
    x, table, new_cache = _run_with_state(p, x, rt, cache, table,
                                          valid=valid)
    x = norm(p["final_norm"], x, rt)
    logits = lm_head(p, last_valid(x, valid), rt)[:, 0]
    return logits, new_cache, table


def prefill(p: Params, tokens: jax.Array, rt: Runtime, table: jax.Array,
            cache, prefix_embeds=None):
    """Bulk prefill = forward_chunk over the whole prompt (fresh state)."""
    zero = jnp.zeros((tokens.shape[0],), jnp.int32)
    return forward_chunk(p, tokens, rt, table, cache, zero)


def decode_step(p: Params, token: jax.Array, rt: Runtime, table: jax.Array,
                cache, pos: jax.Array):
    """Pooled decode = forward_chunk at width T = 1.  token: [B]."""
    return forward_chunk(p, token[:, None], rt, table, cache, pos)


def declare_fold_slots(spec: DeviceFoldSpec, cfg: ModelConfig) -> None:
    spec.declare("app", "loss", "train_step", "count")
