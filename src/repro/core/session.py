"""XFASession — wire the three XFA layers around a training/serving step.

The session is the user-facing object (the paper's 'Scaler runtime' +
'offline visualizer' pair):

  L1 host layer    TRACER records every framework boundary around dispatch
  L2 device layer  a DeviceFoldSpec table threads through the jitted step
  L3 static layer  trace-time analytic costs + compiled-HLO collective flows

`report()` merges everything into one FoldedTable and renders the paper's
component view / API view / flow matrix, plus the TPU-specific collective
flow summary that feeds the roofline collective term.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from . import tracer as xfa
from .attribution import (attribute_parallel, attribute_serial,
                          combine_phases, imbalance_report, wait_split)
from .device_fold import STATIC_COSTS, DeviceFoldSpec
from .folding import FoldedTable
from .hlo_flows import (CollectiveSummary, find_redundant_gathers,
                        parse_collective_flows)
from .views import (View, api_view, api_view_by_caller, component_view,
                    flow_matrix, metric_view, render_flow_matrix)

#: component vocabulary used to resolve HLO op_name scopes; model code uses
#: jax.named_scope with these names.
KNOWN_COMPONENTS = (
    "embed", "attention", "mlp", "moe", "ssm", "mlstm", "slstm", "norm",
    "rope", "lm_head", "loss", "optimizer", "grads", "collective", "data",
    "ckpt", "serve", "decode", "prefill", "encoder", "decoder", "cross",
    "runtime", "pipeline", "app",
)


@dataclass
class XFAReport:
    folded: FoldedTable
    collectives: Optional[CollectiveSummary]
    wall_ns: float
    n_steps: int

    def component_view(self, component: str,
                       total_ns: Optional[float] = None) -> View:
        if component == "app" and total_ns is None:
            total_ns = self.wall_ns
        return component_view(self.folded, component, total_ns)

    def api_view(self, component: str) -> View:
        return api_view(self.folded, component)

    def api_view_by_caller(self, component: str) -> View:
        return api_view_by_caller(self.folded, component)

    def metric_view(self, metric: str) -> View:
        return metric_view(self.folded, metric)

    def render(self, components: Sequence[str] = ("app",)) -> str:
        parts = [f"XFA report: {self.n_steps} steps, "
                 f"wall {self.wall_ns/1e9:.3f}s"]
        for c in components:
            parts.append(self.component_view(c).render())
            parts.append(self.api_view(c).render())
        parts.append(render_flow_matrix(self.folded))
        if self.collectives and self.collectives.flows:
            parts.append("Collective flows (wire bytes/device/step):")
            for comp, b in sorted(self.collectives.by_component.items(),
                                  key=lambda kv: -kv[1]):
                parts.append(f"  {comp:<20} {b/1e6:>12.3f} MB")
            for axis, b in sorted(self.collectives.by_axis.items()):
                parts.append(f"  axis {axis:<15} {b/1e6:>12.3f} MB")
            red = find_redundant_gathers(self.collectives.flows)
            if red:
                parts.append("  redundant collectives (same shape+site):")
                for desc, n in red[:10]:
                    parts.append(f"    {n}x {desc}")
        return "\n\n".join(parts)

    def to_json(self) -> dict:
        return {
            "wall_ns": self.wall_ns,
            "n_steps": self.n_steps,
            "folded": self.folded.to_json(),
            "collectives": {
                "by_component": self.collectives.by_component,
                "by_kind": self.collectives.by_kind,
                "by_axis": self.collectives.by_axis,
                "total_wire_bytes": self.collectives.total_wire_bytes,
            } if self.collectives else None,
        }


class XFASession:
    """Profiles a run: host folds + device fold table + HLO collective flows.

    Usage:
        spec = DeviceFoldSpec(); model declares slots; spec.freeze()
        sess = XFASession(device_spec=spec, dp_degree=16)
        table = sess.init_device_table()
        ... step = jit(step_fn) ; table carried through ...
        sess.observe_step(wall_ns)       # per dispatched step
        sess.finish_device(table)        # fetch + fold once at the end
        sess.attach_hlo(compiled.as_text(), mesh_axes={...})
        report = sess.report()
    """

    def __init__(self, device_spec: Optional[DeviceFoldSpec] = None,
                 dp_degree: int = 1, tracer=None) -> None:
        self.device_spec = device_spec
        self.dp_degree = dp_degree
        self.tracer = tracer or xfa.TRACER
        self.n_steps = 0
        self.wall_ns = 0.0
        self._device_fold: Optional[FoldedTable] = None
        self._collectives: Optional[CollectiveSummary] = None
        self._static_snapshot: Optional[FoldedTable] = None

    # -- device table ------------------------------------------------------
    def init_device_table(self):
        if self.device_spec is None:
            raise RuntimeError("no DeviceFoldSpec attached")
        return self.device_spec.init_table()

    def finish_device(self, table) -> None:
        arr = np.asarray(table, dtype=np.float64)
        self._device_fold = self.device_spec.fold(arr, group="device")

    # -- step accounting -----------------------------------------------------
    def observe_step(self, wall_ns: float, n: int = 1) -> None:
        self.n_steps += n
        self.wall_ns += wall_ns

    # -- static layers -------------------------------------------------------
    def snapshot_static(self) -> None:
        """Capture trace-time analytic costs; call right after tracing/jit."""
        self._static_snapshot = STATIC_COSTS.as_folded()

    def attach_hlo(self, hlo_text: str,
                   mesh_axes: Optional[Dict[str, int]] = None) -> None:
        flows = parse_collective_flows(hlo_text, KNOWN_COMPONENTS, mesh_axes)
        self._collectives = CollectiveSummary.build(flows)

    # -- report --------------------------------------------------------------
    def host_folds(self) -> List[FoldedTable]:
        return FoldedTable.from_set(self.tracer.tables,
                                    rates=self.tracer.sample_rates())

    def folded_all(self, include_replicated: bool = True) -> FoldedTable:
        """Raw merge of host + device + static folds — no attribution, no
        step scaling.  This is what persists to profile shards: host totals
        stay additive, so shards from N processes reduce to exactly the
        profile one process doing all the work would have written.

        The device and static folds hold *replicated* (globally identical)
        values in SPMD: every rank traces the same program and fetches the
        same fold vector.  In a multi-process run only one rank should shard
        them (`include_replicated=False` on the others), or the cross-rank
        reduce would count them once per rank."""
        merged = FoldedTable.merge_all(self.host_folds())
        if not include_replicated:
            return merged
        if self._device_fold is not None:
            merged = merged.merge(self._device_fold)
        static = self._static_snapshot
        if static is None:
            static = STATIC_COSTS.as_folded()
        if len(static):
            merged = merged.merge(static)
        return merged

    def snapshot(self, path: str, meta: Optional[Dict[str, Any]] = None,
                 include_replicated: bool = True) -> str:
        """Persist the current raw profile as one snapshot shard (atomic)."""
        from repro.profile import ProfileSnapshot  # avoid import cycle
        snap_meta: Dict[str, Any] = {"n_steps": self.n_steps,
                                     "wall_ns": self.wall_ns}
        snap_meta.update(meta or {})
        return ProfileSnapshot.from_folded(
            self.folded_all(include_replicated), meta=snap_meta).save(path)

    def report(self, parallel_groups: Optional[Dict[str, int]] = None
               ) -> XFAReport:
        """Merge host (per-thread), device, and static folds.

        `parallel_groups`: thread-group name -> lane count; groups listed are
        attributed as parallel phases (duration / lanes), others serial.
        """
        phases = []
        for fold in self.host_folds():
            lanes = (parallel_groups or {}).get(fold.group, 1)
            phases.append(attribute_parallel(fold, lanes) if lanes > 1
                          else attribute_serial(fold))
        merged = combine_phases(phases)
        if self._device_fold is not None:
            merged = merged.merge(self._device_fold)
        static = self._static_snapshot
        if static is None:
            static = STATIC_COSTS.as_folded()
        # static costs are per traced step; scale to the observed step count
        if self.n_steps > 1 and len(static):
            scaled = FoldedTable(group="static")
            for k, e in static.edges.items():
                e2 = e.merge(type(e)())  # copy
                e2.metrics = {m: v * self.n_steps for m, v in e.metrics.items()}
                e2.count = e.count * self.n_steps
                scaled.edges[k] = e2
            static = scaled
        merged = merged.merge(static)
        return XFAReport(merged, self._collectives, self.wall_ns, self.n_steps)

    def imbalance(self, threshold: float = 4.0):
        by_group: Dict[str, List[FoldedTable]] = {}
        for fold in self.host_folds():
            by_group.setdefault(fold.group, []).append(fold)
        return imbalance_report(by_group, threshold)

    def dump(self, path: str) -> None:
        rep = self.report()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(rep.to_json(), f, indent=1)
