"""Manual-TP MLP (parallel/tp.py): numerical equivalence vs the pjit path.

Runs under an 8-device CPU mesh in a subprocess (device count must be set
before jax init)."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from repro.configs import get_smoke
    from repro.models import build_model
    from repro.parallel.axes import runtime_mesh
    from repro.core.hlo_analysis import analyze_module

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(get_smoke("tinyllama_1_1b"), d_ff=256)
    tok = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok,
             "mask": jnp.ones_like(tok, jnp.float32)}
    outs = {}
    for manual in (False, True):
        c = dataclasses.replace(cfg, manual_tp=manual)
        model = build_model(c, impl="ref")
        params = model.init(jax.random.key(0))
        with runtime_mesh(mesh):
            loss_fn = lambda p: model.loss_fn(p, batch, model.table())[0]
            loss, g = jax.jit(jax.value_and_grad(loss_fn))(params)
            outs[manual] = (float(loss), jax.tree.map(np.asarray, g))
    l0, g0 = outs[False]
    l1, g1 = outs[True]
    assert abs(l0 - l1) < 1e-4, (l0, l1)
    errs = [float(np.max(np.abs(a.astype(np.float32)
                                - b.astype(np.float32))))
            for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1))]
    # grads agree to bf16-cotangent rounding (the ONLY numerics change)
    assert max(errs) < 2e-2, max(errs)

    # also check the gated (SwiGLU) path standalone
    from repro.parallel.tp import col_row_mlp
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.float32)
    wg = jnp.asarray(rng.standard_normal((32, 64)) * 0.1, jnp.float32)
    wu = jnp.asarray(rng.standard_normal((32, 64)) * 0.1, jnp.float32)
    wd = jnp.asarray(rng.standard_normal((64, 32)) * 0.1, jnp.float32)
    with runtime_mesh(mesh):
        y_tp = jax.jit(lambda *a: col_row_mlp(a[0], a[1], a[3], a[2], True))(
            x, wu, wg, wd)
    h = jax.nn.silu(x @ wg) * (x @ wu)
    y_ref = h @ wd
    np.testing.assert_allclose(np.asarray(y_tp), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    print("OK")
""")


@pytest.mark.slow
def test_manual_tp_equivalence_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=400,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
