"""Central fleet collector — spool daemon for streamed snapshot rings.

`python -m repro.profile collect --spool DIR --port P` runs a threaded
TCP server speaking the framed transport (transport.py).  Every client
session is one `(run_id, host)` pair; acknowledged ring entries land in
the spool as

    SPOOL/<run_id>/manifest.json                      (merged run manifest)
    SPOOL/<run_id>/<host>/<shard>.<seq:06d>.xfa.npz   (host's ring entries)

which is exactly a run directory the rest of the profile plane already
understands: `ProfileStore`/`merge`/`report` reduce the newest entry of
every `<host>/<shard>` ring (host-qualified stems, so two hosts' rank-0
rings never collide), `timeline` walks each ring, `query` indexes the
manifests, and `gc` applies retention per host subdirectory.

Durability contract: a snapshot is acked only after its sha256 matched
and the bytes were written via tmp + rename into the host directory —
the spool NEVER holds a torn file, and the ack state IS the spool (a
restarted collector rebuilds it by listing the run's host dir), so
resume needs no side journal.

The collector folds its own ingest metrics through the process tracer
(`collector.frame` / `collector.ingest_bytes` / `collector.dedup_hit` /
`collector.reject` counts, per-frame `collector.ingest` durations, a
`collector.client_lag` gauge of how far behind each hello's resume
point was) — the profile plane observes itself; `--self-profile` spools
those folds as a run of their own (`SPOOL/_collector`).
"""

from __future__ import annotations

import json
import os
import socketserver
import tempfile
import threading
import time
from typing import Dict, Optional, Tuple

from ..core import tracer as xfa
from .index import MANIFEST_NAME, RunManifest, register_run
from .snapshot import SNAPSHOT_SUFFIX
from .store import snapshot_name, split_snapshot_name
from .transport import (MAX_FRAME_BYTES, PROTO_VERSION, Disconnect,
                        FrameError, frame_checksum, recv_frame, send_frame)

#: collector-side run id for the collector's own profile shard ring
SELF_RUN_ID = "_collector"


def _safe_part(name: str, what: str) -> str:
    """Reject path-escaping run/host/shard names from the wire: the
    spool layout is attacker-adjacent input, '../' must die here."""
    if (not name or name != os.path.basename(name) or name.startswith(".")
            or "/" in name or "\\" in name or os.sep in name):
        raise FrameError(f"illegal {what} {name!r} in frame")
    return name


class _Handler(socketserver.BaseRequestHandler):
    """One client connection: hello -> ack_state, then a frame loop."""

    def handle(self) -> None:  # noqa: C901 - one dispatch loop
        col: Collector = self.server.collector        # type: ignore
        sock = self.request
        sock.settimeout(col.timeout)
        run_id = host = None
        try:
            header, _ = recv_frame(sock, col.max_frame_bytes)
            if header.get("type") != "hello":
                raise FrameError(f"expected hello, got {header.get('type')!r}")
            if int(header.get("proto", 0)) != PROTO_VERSION:
                raise FrameError(
                    f"protocol {header.get('proto')!r} != {PROTO_VERSION}")
            run_id = _safe_part(str(header.get("run_id", "")), "run_id")
            host = _safe_part(str(header.get("host", "")), "host")
            acked = col.ack_state(run_id, host)
            send_frame(sock, {"type": "ack_state", "acked": acked})
            xfa.TRACER.count_event("collector", "session")
            while True:
                header, payload = recv_frame(sock, col.max_frame_bytes)
                kind = header.get("type")
                if kind == "bye":
                    return
                t0 = time.perf_counter_ns()
                if kind == "snapshot":
                    reply = col.ingest_snapshot(header, payload, acked)
                elif kind == "manifest":
                    reply = col.ingest_manifest(header, payload)
                else:
                    raise FrameError(f"unexpected frame type {kind!r}")
                xfa.TRACER.record_duration(
                    "collector", "ingest", time.perf_counter_ns() - t0)
                send_frame(sock, reply)
        except Disconnect:
            pass            # client went away; acked state is durable
        except (FrameError, OSError, ValueError) as e:
            xfa.TRACER.count_event("collector", "protocol_error")
            try:
                send_frame(sock, {"type": "error", "reason": str(e)})
            except OSError:
                pass


class _Server(socketserver.ThreadingTCPServer):
    daemon_threads = True
    allow_reuse_address = True


class Collector:
    """The spool daemon body (the `collect` subcommand, importable)."""

    def __init__(self, spool: str, host: str = "127.0.0.1", port: int = 0,
                 timeout: float = 30.0,
                 max_frame_bytes: int = MAX_FRAME_BYTES) -> None:
        self.spool = spool
        self.timeout = timeout
        self.max_frame_bytes = max_frame_bytes
        os.makedirs(spool, exist_ok=True)
        self._server = _Server((host, port), _Handler)
        self._server.collector = self        # type: ignore[attr-defined]
        self._thread: Optional[threading.Thread] = None
        self._manifest_locks: Dict[str, threading.Lock] = {}
        self._lock = threading.Lock()

    # -- lifecycle ----------------------------------------------------------
    @property
    def address(self) -> Tuple[str, int]:
        return self._server.server_address[:2]

    @property
    def port(self) -> int:
        return self.address[1]

    def start(self) -> "Collector":
        """Serve on a daemon thread (tests / in-process embedding)."""
        self._thread = threading.Thread(target=self._server.serve_forever,
                                        name="xfa-collector", daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        self._server.serve_forever()

    def shutdown(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "Collector":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- spool state --------------------------------------------------------
    def host_dir(self, run_id: str, host: str) -> str:
        return os.path.join(self.spool, run_id, host)

    def ack_state(self, run_id: str, host: str) -> Dict[str, int]:
        """shard stem -> max spooled seq for one (run_id, host) — rebuilt
        from the spool itself, so a collector restart resumes exactly."""
        acked: Dict[str, int] = {}
        d = self.host_dir(run_id, host)
        try:
            names = os.listdir(d)
        except (FileNotFoundError, NotADirectoryError):
            return acked
        for name in names:
            if not name.endswith(SNAPSHOT_SUFFIX):
                continue
            stem, seq = split_snapshot_name(name)
            acked[stem] = max(acked.get(stem, 0), seq)
        return acked

    # -- frame ingestion ----------------------------------------------------
    def ingest_snapshot(self, header: Dict, payload: bytes,
                        acked: Dict[str, int]) -> Dict:
        run_id = _safe_part(str(header.get("run_id", "")), "run_id")
        host = _safe_part(str(header.get("host", "")), "host")
        shard = _safe_part(str(header.get("shard", "")), "shard")
        seq = int(header.get("seq", 0))
        if seq < 1:
            return {"type": "reject", "shard": shard, "seq": seq,
                    "reason": f"sequence {seq} out of range"}
        want = str(header.get("sha256", ""))
        if len(payload) != int(header.get("length", -1)) \
                or frame_checksum(payload) != want:
            xfa.TRACER.count_event("collector", "reject")
            return {"type": "reject", "shard": shard, "seq": seq,
                    "reason": "checksum/length mismatch — re-send"}
        # per-client resume lag: how far beyond the previous ack this
        # frame lands (1 == in-order next entry, more == catching up)
        xfa.TRACER.record_gauge("collector", "client_lag",
                                float(seq - acked.get(shard, 0)))
        xfa.TRACER.count_event("collector", "frame")
        xfa.TRACER.count_event("collector", "ingest_bytes", n=len(payload))
        d = self.host_dir(run_id, host)
        path = os.path.join(d, snapshot_name(shard, seq))
        if os.path.exists(path):
            # dedup (a replayed frame after an ack the client never saw,
            # or two publishers sharing a run dir): the spool entry is
            # content-addressed by (run, host, shard, seq) + checksum
            with open(path, "rb") as f:
                have = f.read()
            if frame_checksum(have) == want:
                xfa.TRACER.count_event("collector", "dedup_hit")
                acked[shard] = max(acked.get(shard, 0), seq)
                return {"type": "ack", "shard": shard, "seq": seq,
                        "dedup": True}
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(payload)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        acked[shard] = max(acked.get(shard, 0), seq)
        return {"type": "ack", "shard": shard, "seq": seq, "dedup": False}

    def ingest_manifest(self, header: Dict, payload: bytes) -> Dict:
        run_id = _safe_part(str(header.get("run_id", "")), "run_id")
        _safe_part(str(header.get("host", "")), "host")
        if len(payload) != int(header.get("length", -1)) or \
                frame_checksum(payload) != str(header.get("sha256", "")):
            xfa.TRACER.count_event("collector", "reject")
            return {"type": "reject", "shard": MANIFEST_NAME, "seq": 0,
                    "reason": "checksum/length mismatch — re-send"}
        try:
            doc = json.loads(payload.decode("utf-8"))
            incoming = RunManifest.from_json(doc)
        except (UnicodeDecodeError, json.JSONDecodeError, ValueError) as e:
            return {"type": "reject", "shard": MANIFEST_NAME, "seq": 0,
                    "reason": f"manifest does not parse: {e}"}
        run_dir = os.path.join(self.spool, run_id)
        # serialize per-run merges locally; register_run's flock guards
        # against OTHER processes touching the same spool
        with self._lock:
            lock = self._manifest_locks.setdefault(run_id, threading.Lock())
        with lock:
            m = register_run(
                run_dir, config=incoming.config, arch=incoming.arch,
                mesh_shape=incoming.mesh_shape, mesh_axes=incoming.mesh_axes,
                label=incoming.label, kind=incoming.kind,
                meta=incoming.meta,
                started_at=incoming.started_at or None)
            # union the publishers' writer entries into the spool manifest
            # (register_run above only appended the collector itself)
            known = {(w.get("label"), w.get("host"), w.get("pid"))
                     for w in m.writers}
            extra = [w for w in incoming.writers
                     if (w.get("label"), w.get("host"), w.get("pid"))
                     not in known]
            if extra:
                m.writers.extend(extra)
                m.save()
        xfa.TRACER.count_event("collector", "manifest")
        return {"type": "ack", "shard": MANIFEST_NAME, "seq": 0,
                "dedup": False}

    # -- self-observation ---------------------------------------------------
    def write_self_shard(self) -> Optional[str]:
        """Spool the collector's own tracer folds as a run of their own
        (`SPOOL/_collector`): the profile plane observes itself."""
        from .store import ProfileStore, tracer_folded
        folded = tracer_folded()
        if not len(folded):
            return None
        run_dir = os.path.join(self.spool, SELF_RUN_ID)
        register_run(run_dir, label="collector", kind="collect",
                     meta={"spool": os.path.abspath(self.spool)})
        return ProfileStore(run_dir).write_shard(folded, label="collector")


def collect_main(spool: str, host: str, port: int, timeout: float,
                 max_frame_bytes: int, max_seconds: float,
                 self_profile: bool, self_profile_interval_s: float) -> int:
    """The `collect` subcommand body: serve until SIGINT/SIGTERM (or
    `max_seconds`, for CI lanes), periodically spooling self metrics."""
    import signal
    col = Collector(spool, host=host, port=port, timeout=timeout,
                    max_frame_bytes=max_frame_bytes)
    bind_host, bind_port = col.address
    print(f"collector listening on {bind_host}:{bind_port} "
          f"spool={os.path.abspath(spool)}", flush=True)
    stop = threading.Event()
    for sig in (signal.SIGINT, signal.SIGTERM):
        try:
            signal.signal(sig, lambda *_: stop.set())
        except ValueError:           # not the main thread (embedded use)
            break
    col.start()
    deadline = time.monotonic() + max_seconds if max_seconds > 0 else None
    next_self = time.monotonic() + self_profile_interval_s
    try:
        while not stop.is_set():
            if deadline is not None and time.monotonic() >= deadline:
                break
            stop.wait(timeout=0.2)
            if self_profile and time.monotonic() >= next_self:
                col.write_self_shard()
                next_self = time.monotonic() + self_profile_interval_s
    finally:
        if self_profile:
            col.write_self_shard()
        col.shutdown()
    print(f"collector stopped; spool={os.path.abspath(spool)}", flush=True)
    return 0
