"""Adaptive overhead governor — per-edge sampling with unbiased scale-up.

Scaler's pitch is profiling cheap enough to leave on in production
(~20% at 100% tracing, paper Tables 1/3).  This module makes that claim
*adaptive* in the ScALPEL shape (PAPERS.md: scalable adaptive
lightweight performance evaluation — back instrumentation off where it
costs the most) without giving up the paper's Table 6 argument against
naive sampling:

  * COUNTING IS ALWAYS ON.  Back-off only ever drops the *timing
    bracket* (two timestamps + the five-column record); every call still
    folds an exact `count` increment.  Short-burst edges are therefore
    never lost — the failure mode benchmarks/sampling.py reproduces for
    time-based samplers cannot happen here.
  * Back-off is COUNT-PROPORTIONAL and PER-EDGE: each edge keeps one
    timed sample in `k` calls (`k` a power of two, decided per edge), so
    an edge firing 10x as often still contributes 10x the samples, and a
    cold edge stays at sample-every-call.
  * Scale-up is UNBIASED: a timed sample standing for `k` calls folds
    `total_ns`/`child_ns` (and, where the edge carries one, histogram
    bucket increments) scaled by `k`, while `count` stays exact from the
    always-on counter.  Averaged over the `k` sampling phases the scaled
    fold equals the full-trace fold exactly (property-tested in
    tests/test_sampler.py).

The controller's self-cost estimate is deliberately cheap: every
`recalc_every` events of an edge it divides the elapsed wall time into
the window to get the edge's event rate, multiplies by the calibrated
per-bracket cost, and compares the *sum over edges* against the
configured budget (`TrainConfig.xfa_overhead_budget` /
`ServeConfig.xfa_overhead_budget`).  All hot edges then converge to the
smallest power-of-two stride that brings estimated total bracket
overhead back under budget; when load drops they relax back toward
stride 1.  Per-slot state lives in plain python lists — increments are
GIL-serialized in CPython, and a lost controller increment under racing
threads only perturbs the *heuristic*, never the authoritative shadow
table counts.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Optional

perf_ns = time.perf_counter_ns

#: floor for the calibrated bracket cost — a degenerate 0 estimate would
#: disable back-off entirely on very fast clocks.
MIN_BRACKET_NS = 50.0


def estimate_bracket_ns(iters: int = 4000) -> float:
    """Measure the cost of one timing bracket (enter + exit + record) on
    a scratch tracer: the difference between a traced no-op and a plain
    no-op call, per invocation.  Runs in ~a few ms at import-of-governor
    time, never on the hot path."""
    from .tracer import Tracer

    t = Tracer()

    @t.api("xfa_calibrate")
    def _traced() -> None:
        return None

    def _plain() -> None:
        return None

    for _ in range(256):          # warm caches, intern the slot
        _traced()
        _plain()
    t0 = perf_ns()
    for _ in range(iters):
        _plain()
    base = perf_ns() - t0
    t1 = perf_ns()
    for _ in range(iters):
        _traced()
    traced = perf_ns() - t1
    return max((traced - base) / iters, MIN_BRACKET_NS)


class SamplerController:
    """Per-edge sampling decisions for the tracer's timing brackets.

    `observe(slot)` is the hot-path entry: it counts the event and
    returns the scale `k` to time this call with (fold stats * k), or 0
    when the call should fold a count only.  Strides start at 1
    (sample every call) and move in powers of two.
    """

    def __init__(self, budget_fraction: float, recalc_every: int = 256,
                 bracket_ns: Optional[float] = None,
                 max_stride: int = 1 << 16,
                 clock: Callable[[], int] = perf_ns) -> None:
        if budget_fraction <= 0:
            raise ValueError("budget_fraction must be > 0 (use "
                             "Tracer.set_overhead_budget(0) to detach)")
        self.budget = float(budget_fraction)
        self.recalc_every = int(recalc_every)
        self.max_stride = int(max_stride)
        self._clock = clock
        self.bracket_ns = float(bracket_ns) if bracket_ns \
            else estimate_bracket_ns()
        self._lock = threading.Lock()      # slot-state growth only
        self._stride = []                  # current 1-in-k stride per slot
        self._seen = []                    # cumulative events per slot
        self._timed = []                   # cumulative timed samples per slot
        self._window_start = []            # wall ns at last recalc per slot
        self._full_cost = []               # est. overhead fraction at k=1
        self._total_full = 0.0             # sum of _full_cost over slots

    # -- hot path ---------------------------------------------------------
    def observe(self, slot: int) -> int:
        """Count one event on `slot`; return the scale to time it with
        (>= 1), or 0 to skip the timing bracket for this call."""
        if slot >= len(self._stride):
            self._ensure(slot)
        n = self._seen[slot] + 1
        self._seen[slot] = n
        if n % self.recalc_every == 0:
            self._recalc(slot)
        k = self._stride[slot]
        if k <= 1 or n % k == 0:
            self._timed[slot] += 1
            return k
        return 0

    # -- slow paths -------------------------------------------------------
    def _ensure(self, slot: int) -> None:
        with self._lock:
            now = self._clock()
            while len(self._stride) <= slot:
                self._stride.append(1)
                self._seen.append(0)
                self._timed.append(0)
                self._window_start.append(now)
                self._full_cost.append(0.0)

    def _recalc(self, slot: int) -> None:
        """Re-estimate this edge's full-trace cost (bracket cost x event
        rate over the window just closed) and re-derive its stride from
        the total estimated overhead vs the budget.  Cold edges recalc
        rarely and keep a stale (tiny) cost contribution — acceptable
        for a governor whose decisions only move timing fidelity."""
        now = self._clock()
        dt = now - self._window_start[slot]
        self._window_start[slot] = now
        if dt <= 0:
            return
        full = self.bracket_ns * self.recalc_every / dt
        self._total_full += full - self._full_cost[slot]
        self._full_cost[slot] = full
        need = self._total_full / self.budget
        k = 1
        while k < need and k < self.max_stride:
            k <<= 1
        self._stride[slot] = k

    # -- read-out ---------------------------------------------------------
    def rates(self) -> Dict[int, float]:
        """Effective per-slot sampling rate (timed / seen) for every slot
        that was actually subsampled; fully-timed slots are omitted
        (rate 1.0 is the implicit default everywhere downstream)."""
        out: Dict[int, float] = {}
        for slot, seen in enumerate(self._seen):
            if seen and self._timed[slot] < seen:
                out[slot] = self._timed[slot] / seen
        return out

    def strides(self) -> Dict[int, int]:
        """Slots currently backed off (stride > 1) -> their stride."""
        return {s: k for s, k in enumerate(self._stride) if k > 1}

    def stride(self, slot: int) -> int:
        return self._stride[slot] if slot < len(self._stride) else 1

    def set_stride(self, slot: int, k: int) -> None:
        """Pin a slot's stride (tests / manual override).  `k` must be a
        power of two; the next `_recalc` may move it again."""
        if k < 1 or (k & (k - 1)):
            raise ValueError(f"stride must be a power of two, got {k}")
        self._ensure(slot)
        self._stride[slot] = k

    def reset(self) -> None:
        """Forget all counters and strides (paired with Tracer.reset —
        slot ids survive, so state arrays keep their length)."""
        with self._lock:
            n = len(self._stride)
            now = self._clock()
            self._stride = [1] * n
            self._seen = [0] * n
            self._timed = [0] * n
            self._window_start = [now] * n
            self._full_cost = [0.0] * n
            self._total_full = 0.0


def fold_event(table, slot: int, dur_ns: int, k: int,
               hist: bool = False) -> None:
    """Fold one governed event into a ShadowTable given the sampling
    decision `k` from `SamplerController.observe`: k == 0 counts only,
    k == 1 is a plain full fold, k > 1 folds the sample scaled by k
    (counts stay exact either way).  This is the clock-free, thread-free
    twin of the tracer hot path — tests and benchmarks replay synthetic
    event streams through it deterministically."""
    if k == 0:
        table.record_count(slot)
    elif k == 1:
        table.record(slot, dur_ns, 0)
        if hist:
            table.record_hist(slot, dur_ns)
    else:
        table.record_scaled(slot, dur_ns, 0, k)
        if hist:
            table.record_hist(slot, dur_ns, k)
