"""Run the full dry-run matrix: every (arch × shape) × {single-pod, multi-pod}.

Each cell runs in a FRESH subprocess (the 512-device XLA flag must be set
before jax initializes, and XLA leaks compile-cache memory across big
modules). Failures are logged and the sweep continues; completed cells are
skipped on re-run (idempotent — restart-friendly like everything else here).

Usage: python -m repro.launch.dryrun_all [--multi-pod-only|--single-pod-only]
       [--arch A] [--force]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    "tinyllama_1_1b", "internvl2_1b", "seamless_m4t_large_v2",
    "deepseek_v2_lite_16b", "zamba2_2_7b", "xlstm_1_3b",
    "starcoder2_7b", "qwen3_14b", "phi3_5_moe_42b", "granite_20b",
]
SHAPES = ["train_4k", "decode_32k", "prefill_32k", "long_500k"]

#: per-arch gradient-accumulation microbatches for train_4k: chosen so saved
#: layer activations (~L x B_dev x S x d_model x 2B / micro) fit ~4 GiB HBM
MICRO = {
    "granite_20b": 16, "starcoder2_7b": 8, "qwen3_14b": 8,
    "tinyllama_1_1b": 2, "zamba2_2_7b": 8, "deepseek_v2_lite_16b": 4,
    "phi3_5_moe_42b": 8, "xlstm_1_3b": 8, "internvl2_1b": 1,
    "seamless_m4t_large_v2": 4,
}

OUT = "artifacts/dryrun"


def cell_done(arch: str, shape: str, multi_pod: bool) -> bool:
    suffix = "multipod" if multi_pod else "pod"
    path = os.path.join(OUT, f"{arch}_{shape}_{suffix}.json")
    if not os.path.exists(path):
        return False
    try:
        with open(path) as f:
            json.load(f)
        return True
    except Exception:
        return False


def run_one(arch: str, shape: str, multi_pod: bool,
            timeout: int = 1500) -> str:
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape]
    if shape == "train_4k":
        micro = MICRO.get(arch, 4)
        # microbatches must keep global_batch/micro divisible by the DP
        # extent (pod x data = 32 on the multi-pod mesh): 256/(16x32) is
        # uneven and GSPMD pads+gathers — measured 16x collective blowup on
        # granite multi-pod (EXPERIMENTS.md §Perf granite iteration 1)
        if multi_pod:
            micro = min(micro, 8)
        cmd += ["--microbatches", str(micro)]
    if multi_pod:
        cmd += ["--multi-pod"]
    env = dict(os.environ, PYTHONPATH="src")
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
    except subprocess.TimeoutExpired:
        return f"TIMEOUT after {timeout}s"
    dt = time.time() - t0
    if proc.returncode != 0:
        tail = (proc.stderr or proc.stdout).strip().splitlines()[-12:]
        return f"FAIL ({dt:.0f}s): " + " | ".join(tail)
    out = [ln for ln in proc.stdout.splitlines() if ln.startswith(("OK", "SKIP"))]
    return f"{out[0] if out else 'OK'} [{dt:.0f}s]"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--arch", default="")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = [False, True]
    if args.multi_pod_only:
        meshes = [True]
    if args.single_pod_only:
        meshes = [False]
    archs = [args.arch] if args.arch else ARCHS

    os.makedirs(OUT, exist_ok=True)
    log_path = os.path.join(OUT, "sweep_log.txt")
    failures = 0
    with open(log_path, "a") as log:
        for multi_pod in meshes:
            for shape in SHAPES:
                for arch in archs:
                    tag = f"{arch}:{shape}:{'multipod' if multi_pod else 'pod'}"
                    if not args.force and cell_done(arch, shape, multi_pod):
                        continue
                    msg = run_one(arch, shape, multi_pod)
                    line = f"{time.strftime('%H:%M:%S')} {tag:60s} {msg}"
                    print(line, flush=True)
                    log.write(line + "\n")
                    log.flush()
                    if msg.startswith(("FAIL", "TIMEOUT")):
                        failures += 1
    print(f"sweep finished, failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
