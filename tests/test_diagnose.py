"""Diagnosis subsystem: every built-in detector fires on a synthetic
profile built to exhibit exactly its pathology and stays silent on the
healthy baseline; calibration fits bands both the `diff` gate and the
detectors consume; `diagnose` runs end-to-end (deterministically) on a
real trainer run and a real serving run."""

import json
import os

import pytest

from repro.core.folding import EdgeStats, FoldedTable, fold_event_log
from repro.core.shadow import KIND_WAIT
from repro.core.histogram import hist_of
from repro.analysis import (CachePressure, CallAmplification,
                            DiagnosisContext,
                            DriftRegression, EdgeBand, FlowGraph,
                            HotEdgeConcentration, QueueSaturation,
                            RankImbalance, SloViolation, Thresholds,
                            WaitDominance, build_context,
                            builtin_detectors, calibrate_ring,
                            calibrate_runs, diagnose, run_detectors)
from repro.profile import ProfileStore, build_timelines, register_run
from repro.profile.diff import diff_profiles

MS = 1_000_000


def edge(count, total_ns, *, child_ns=0, kind=0):
    return EdgeStats(count=count, total_ns=total_ns, child_ns=child_ns,
                     min_ns=1, max_ns=max(total_ns, 1), kind=kind)


#: a profile every detector considers healthy: modest wait share, spread
#: self time, balanced counts.
def healthy_table(scale=1):
    return FoldedTable({
        ("app", "runtime", "dispatch"): edge(100, 90 * MS * scale,
                                             child_ns=10 * MS * scale),
        ("app", "runtime", "sync"): edge(100, 10 * MS * scale,
                                         kind=KIND_WAIT),
        ("app", "glibc", "read"): edge(500, 30 * MS * scale),
        ("app", "glibc", "write"): edge(400, 25 * MS * scale),
        ("runtime", "alloc", "malloc"): edge(200, 5 * MS * scale),
    })


def ctx_of(table, **kw):
    return DiagnosisContext(graph=FlowGraph.from_folded(table), **kw)


def write_ring(root, cumulative_tables, label="t"):
    store = ProfileStore(str(root))
    for i, t in enumerate(cumulative_tables, start=1):
        store.write_shard(t, label=label, meta={"step": i})
    return str(root)


# ------------------------------------------------------------ detectors ----
class TestWaitDominance:
    def test_fires_on_wait_heavy_component(self):
        t = FoldedTable({
            ("app", "runtime", "dispatch"): edge(100, 100 * MS),
            ("app", "runtime", "device_sync"): edge(100, 900 * MS,
                                                    kind=KIND_WAIT),
        })
        [f] = WaitDominance().detect(ctx_of(t))
        assert f.severity == "crit" and f.subject == "component:runtime"
        assert f.evidence["wait_share"] == pytest.approx(0.9)
        assert f.evidence["top_wait_edge"] == \
            ["app", "runtime", "device_sync"]

    def test_warn_between_bounds(self):
        t = FoldedTable({
            ("app", "runtime", "dispatch"): edge(100, 500 * MS),
            ("app", "runtime", "device_sync"): edge(100, 500 * MS,
                                                    kind=KIND_WAIT),
        })
        [f] = WaitDominance().detect(ctx_of(t))
        assert f.severity == "warn"

    def test_silent_on_healthy_and_below_floor(self):
        assert WaitDominance().detect(ctx_of(healthy_table())) == []
        tiny = FoldedTable({  # 90% wait but under the evidence floor
            ("app", "x", "w"): edge(1, 900, kind=KIND_WAIT),
            ("app", "x", "c"): edge(1, 100),
        })
        assert WaitDominance().detect(ctx_of(tiny)) == []


class TestHotEdgeConcentration:
    def test_fires_when_one_edge_owns_self_time(self):
        t = FoldedTable({
            ("app", "glibc", "read"): edge(1000, 95 * MS),
            ("app", "glibc", "write"): edge(10, 5 * MS),
        })
        [f] = HotEdgeConcentration().detect(ctx_of(t))
        assert f.severity == "crit"
        assert f.subject == "edge:app -> glibc.read"
        assert f.evidence["share"] == pytest.approx(0.95)

    def test_silent_on_spread_or_single_edge(self):
        assert HotEdgeConcentration().detect(ctx_of(healthy_table())) == []
        solo = FoldedTable({("app", "glibc", "read"): edge(10, 50 * MS)})
        assert HotEdgeConcentration().detect(ctx_of(solo)) == []

    def test_wait_edges_do_not_count_as_self_time(self):
        t = FoldedTable({
            ("app", "runtime", "sync"): edge(10, 900 * MS, kind=KIND_WAIT),
            ("app", "runtime", "a"): edge(10, 3 * MS),
            ("app", "runtime", "b"): edge(10, 3 * MS),
        })
        assert HotEdgeConcentration().detect(ctx_of(t)) == []


class TestRankImbalance:
    def _shards(self, *scales):
        return {f"train-r{i}": FlowGraph.from_folded(healthy_table(s))
                for i, s in enumerate(scales)}

    def test_fires_on_straggler(self):
        ctx = ctx_of(healthy_table(), shard_graphs=self._shards(1, 1, 2))
        [f] = RankImbalance().detect(ctx)
        assert f.subject == "shard:train-r2"
        assert f.severity == "warn"
        assert f.evidence["rel_above_mean"] == pytest.approx(0.5)
        assert f.evidence["widest_component"] == "runtime"

    def test_crit_on_2x_straggler(self):
        ctx = ctx_of(healthy_table(), shard_graphs=self._shards(1, 1, 1, 3))
        [f] = RankImbalance().detect(ctx)
        assert f.severity == "crit"

    def test_silent_when_balanced_or_single_shard(self):
        ctx = ctx_of(healthy_table(), shard_graphs=self._shards(1, 1, 1))
        assert RankImbalance().detect(ctx) == []
        ctx = ctx_of(healthy_table(), shard_graphs=self._shards(5))
        assert RankImbalance().detect(ctx) == []


class TestQueueSaturation:
    def _ring(self, tmp_path, means):
        """Cumulative folds whose queue_wait per-interval mean follows
        `means` (one admit per interval), plus the queue_depth gauge the
        engine folds from a DIFFERENT caller (the loop, not the admit
        bracket)."""
        tables, total = [], 0
        for i, m in enumerate(means, start=1):
            total += int(m)
            tables.append(FoldedTable({
                ("serve", "serve", "queue_wait"): edge(i, total,
                                                       kind=KIND_WAIT),
                ("app", "serve", "queue_depth"): edge(10 * i, 3 * 10 * i),
                ("app", "serve", "decode_tick"): edge(10 * i, 10 * i * MS),
            }))
        return build_timelines(write_ring(tmp_path, tables))

    def test_fires_on_growing_queue_wait(self, tmp_path):
        tls = self._ring(tmp_path, [10_000, 25_000, 60_000])
        ctx = ctx_of(healthy_table(), timelines=tls)
        [f] = QueueSaturation().detect(ctx)
        assert f.severity == "crit"          # 6x growth >= crit_ratio 4
        assert f.subject == "edge:serve -> serve.queue_wait"
        assert f.evidence["means_ns"] == [10_000.0, 25_000.0, 60_000.0]
        # the gauge corroborates despite its different caller component
        assert f.evidence["queue_depth_means"] == [3.0, 3.0, 3.0]

    def test_silent_on_flat_or_shrinking_queue(self, tmp_path):
        tls = self._ring(tmp_path / "flat", [50_000, 52_000, 49_000])
        assert QueueSaturation().detect(
            ctx_of(healthy_table(), timelines=tls)) == []
        tls = self._ring(tmp_path / "down", [80_000, 40_000, 20_000])
        assert QueueSaturation().detect(
            ctx_of(healthy_table(), timelines=tls)) == []

    def test_non_monotone_spike_does_not_fire(self, tmp_path):
        # a one-interval spike that recovers is not saturation
        tls = self._ring(tmp_path, [10_000, 90_000, 11_000, 30_000])
        assert QueueSaturation().detect(
            ctx_of(healthy_table(), timelines=tls)) == []

    def test_trimmed_ring_head_not_used_as_interval(self, tmp_path):
        """After retention trims the ring, its first snapshot is a
        cumulative fold — its run-averaged mean is not an interval sample
        and must not enter the growth baseline."""
        from repro.profile import RetentionPolicy
        store = ProfileStore(str(tmp_path),
                             retention=RetentionPolicy(keep_last=4))
        means, total = [10_000, 10_000, 20_000, 40_000, 80_000], 0
        for i, m in enumerate(means, start=1):
            total += m
            store.write_shard(FoldedTable({
                ("serve", "serve", "queue_wait"): edge(i, total,
                                                       kind=KIND_WAIT)}),
                label="t")
        [tl] = build_timelines(str(tmp_path))
        assert tl.seqs[0] != 1               # ring really was trimmed
        [f] = QueueSaturation().detect(
            ctx_of(healthy_table(), timelines=[tl]))
        # only the 3 TRUE intervals enter: 20k -> 40k -> 80k (4x crit);
        # the trimmed head's run-averaged 10k mean is excluded
        assert f.evidence["means_ns"] == [20_000.0, 40_000.0, 80_000.0]
        assert f.severity == "crit"


class TestCachePressure:
    def _ring(self, tmp_path, in_use, depth, capacity=100):
        """Cumulative folds whose paged-pool gauges follow the given
        per-interval means (one gauge event per interval: mean is
        delta_total / delta_count)."""
        tables, iu_tot, d_tot = [], 0, 0
        for i, (u, d) in enumerate(zip(in_use, depth), start=1):
            iu_tot += int(u)
            d_tot += int(d)
            tables.append(FoldedTable({
                ("app", "serve", "cache_pages_in_use"): edge(i, iu_tot),
                ("app", "serve", "cache_pages_capacity"):
                    edge(i, capacity * i),
                ("app", "serve", "queue_depth"): edge(i, d_tot),
                ("app", "serve", "decode_tick"): edge(10 * i, 10 * i * MS),
            }))
        return build_timelines(write_ring(tmp_path, tables))

    def test_fires_when_pages_saturate_and_queue_grows(self, tmp_path):
        tls = self._ring(tmp_path, in_use=[70, 88, 96], depth=[2, 5, 9])
        [f] = CachePressure().detect(ctx_of(healthy_table(), timelines=tls))
        assert f.severity == "crit"          # 96/100 >= crit_util 0.95
        assert f.detector == "cache-pressure"
        assert "pages" in f.message and "max_cache_pages" in f.message
        assert f.evidence["util"] == pytest.approx(0.96)
        assert f.evidence["capacity_pages"] == 100.0
        assert f.evidence["queue_depth_means"] == [2.0, 5.0, 9.0]

    def test_warn_band_below_crit_util(self, tmp_path):
        tls = self._ring(tmp_path, in_use=[60, 75, 85], depth=[1, 2, 4])
        [f] = CachePressure().detect(ctx_of(healthy_table(), timelines=tls))
        assert f.severity == "warn"          # 0.80 <= 0.85 < 0.95

    def test_silent_when_queue_drains_despite_full_pool(self, tmp_path):
        """A full arena with a SHRINKING queue is a healthy full pipe —
        pages are not the bottleneck."""
        tls = self._ring(tmp_path, in_use=[96, 96, 96], depth=[9, 4, 1])
        assert CachePressure().detect(
            ctx_of(healthy_table(), timelines=tls)) == []

    def test_silent_when_pages_free_while_queue_grows(self, tmp_path):
        """Growing queue with free pages is some OTHER bottleneck
        (queue-saturation's business, not this detector's)."""
        tls = self._ring(tmp_path, in_use=[20, 30, 40], depth=[2, 5, 9])
        assert CachePressure().detect(
            ctx_of(healthy_table(), timelines=tls)) == []

    def test_silent_without_capacity_gauge(self, tmp_path):
        """No capacity edge on the ring (pre-paging shard): never fire
        on utilization it cannot compute."""
        tables = []
        for i in range(1, 4):
            tables.append(FoldedTable({
                ("app", "serve", "cache_pages_in_use"): edge(i, 90 * i),
                ("app", "serve", "queue_depth"): edge(i, 3 * i * i),
            }))
        tls = build_timelines(write_ring(tmp_path, tables))
        assert CachePressure().detect(
            ctx_of(healthy_table(), timelines=tls)) == []


class TestDriftRegression:
    def _run(self, root, deltas):
        tables, tot = [], 0
        for d in deltas:
            tot += d
            tables.append(FoldedTable({
                ("app", "runtime", "dispatch"): edge(1, tot)}))
        return write_ring(root, tables)

    def test_fires_on_trending_drift(self, tmp_path):
        base = self._run(tmp_path / "a", [MS, MS, MS])
        cand = self._run(tmp_path / "b",
                         [MS + MS // 5, MS + MS // 2, 2 * MS])
        ctx = ctx_of(healthy_table(),
                     timelines=build_timelines(cand),
                     baseline_timelines=build_timelines(base))
        [f] = DriftRegression().detect(ctx)
        assert f.severity == "warn"
        assert f.subject == "edge:app -> runtime.dispatch"
        assert f.evidence["growth"] == pytest.approx(1.7 / 3)
        assert f.evidence["delta_of_deltas_ns"] == \
            [MS / 5, MS / 2, float(MS)]

    def test_silent_on_flat_offset_and_identical_runs(self, tmp_path):
        base = self._run(tmp_path / "a", [MS, MS, MS])
        offset = self._run(tmp_path / "b", [2 * MS, 2 * MS, 2 * MS])
        ctx = ctx_of(healthy_table(),
                     timelines=build_timelines(offset),
                     baseline_timelines=build_timelines(base))
        # 2x slower but NOT trending up -> drift detector stays quiet
        # (run-level diff already catches static regressions)
        assert DriftRegression().detect(ctx) == []
        same = self._run(tmp_path / "c", [MS, MS, MS])
        ctx = ctx_of(healthy_table(),
                     timelines=build_timelines(same),
                     baseline_timelines=build_timelines(base))
        assert DriftRegression().detect(ctx) == []

    def test_thresholds_provide_noise_floor(self, tmp_path):
        base = self._run(tmp_path / "a", [MS, MS, MS])
        # rises by 3% per interval: a real trend, but within a calibrated
        # noise band it must NOT fire
        cand = self._run(tmp_path / "b",
                         [MS, MS + 3 * MS // 100, MS + 6 * MS // 100])
        tls_c = build_timelines(cand)
        tls_b = build_timelines(base)
        hot = ctx_of(healthy_table(), timelines=tls_c,
                     baseline_timelines=tls_b)
        quiet = ctx_of(healthy_table(), timelines=tls_c,
                       baseline_timelines=tls_b,
                       thresholds=Thresholds(bands={
                           "app -> runtime.dispatch": {
                               "total_ns": EdgeBand(
                                   n=8, mean=MS, std=MS / 10,
                                   p95=1.2 * MS, lo=0.8 * MS,
                                   hi=1.2 * MS)}}))
        det = DriftRegression(warn_growth=0.01)
        assert det.detect(hot)               # fires without bands
        assert det.detect(quiet) == []       # 3σ floor absorbs the trend


class TestCallAmplification:
    def test_fires_on_count_blowup(self):
        t = FoldedTable({
            ("app", "db", "query"): edge(10, 10 * MS),
            ("db", "net", "send"): edge(100_000, 50 * MS),
        })
        [f] = CallAmplification().detect(ctx_of(t))
        assert f.severity == "crit"          # 10_000x >= crit 1000
        assert f.subject == "chain:app -> db.query => net.send"
        assert f.evidence["ratio"] == pytest.approx(10_000.0)

    def test_denominator_is_total_inbound(self):
        # a rare side entrance must not manufacture a blowup: 100k in via
        # the main edge, 10 via a side edge, 200k out -> ratio 2, silent
        t = FoldedTable({
            ("app", "db", "query"): edge(100_000, 10 * MS),
            ("cron", "db", "query"): edge(10, MS),
            ("db", "net", "send"): edge(200_000, 50 * MS),
        })
        assert CallAmplification().detect(ctx_of(t)) == []

    def test_silent_below_count_floor_and_on_healthy(self):
        t = FoldedTable({
            ("app", "db", "query"): edge(1, MS),
            ("db", "net", "send"): edge(500, MS),   # 500x but < min_count
        })
        assert CallAmplification().detect(ctx_of(t)) == []
        assert CallAmplification().detect(ctx_of(healthy_table())) == []


def serve_table(missed, met, e2e_ms=()):
    """A serving profile with deadline count edges and (optionally) an
    e2e latency histogram — the slo-violation detector's inputs."""
    t = FoldedTable({
        ("app", "serve", "prefill_chunk"): edge(50, 40 * MS),
        ("serve", "serve", "deadline_miss"): edge(missed, 0),
        ("serve", "serve", "deadline_met"): edge(met, 0),
    })
    if e2e_ms:
        e = edge(len(e2e_ms), sum(e2e_ms) * MS)
        e.hist = hist_of([int(ms * MS) for ms in e2e_ms])
        t.edges[("serve", "serve", "e2e")] = e
    return t


class TestSloViolation:
    def test_fires_crit_with_histogram_evidence(self):
        # 8 / 100 tracked = 8% miss rate >= crit_rate 5%
        t = serve_table(8, 92, e2e_ms=[10] * 95 + [50] * 5)
        [f] = SloViolation().detect(ctx_of(t))
        assert f.severity == "crit"
        assert f.subject == "component:serve"
        assert f.evidence["miss_rate"] == pytest.approx(0.08)
        assert f.evidence["missed"] == 8
        assert f.evidence["tracked"] == 100
        # percentile spread read off the e2e histogram (~log-bucket res.)
        assert f.evidence["e2e_p50_ns"] == pytest.approx(10 * MS, rel=0.3)
        assert f.evidence["e2e_p99_ns"] == pytest.approx(50 * MS, rel=0.3)
        assert "e2e p50/p95/p99" in f.message

    def test_warn_between_rates_without_histogram(self):
        [f] = SloViolation().detect(ctx_of(serve_table(2, 98)))
        assert f.severity == "warn"
        assert "e2e_p99_ns" not in f.evidence   # no hist, no spread

    def test_silent_on_quiet_and_untracked_fixtures(self):
        # healthy rate (0 misses), below min_tracked, and no deadline
        # edges at all (deadline tracking disarmed) are all silent
        assert SloViolation().detect(
            ctx_of(serve_table(0, 500, e2e_ms=[10] * 20))) == []
        assert SloViolation().detect(ctx_of(serve_table(1, 3))) == []
        assert SloViolation().detect(ctx_of(healthy_table())) == []


class TestDetectorFramework:
    def test_every_builtin_silent_on_healthy_run(self, tmp_path):
        run = write_ring(tmp_path, [healthy_table(1), healthy_table(2),
                                    healthy_table(3)])
        ctx = build_context(run)
        for det in builtin_detectors():
            assert det.detect(ctx) == [], det.name

    def test_ordering_is_deterministic_and_severity_first(self):
        t = FoldedTable({
            # wait dominance (crit) + hot edge (warn via tuned bound)
            ("app", "runtime", "sync"): edge(10, 900 * MS, kind=KIND_WAIT),
            ("app", "runtime", "dispatch"): edge(10, 100 * MS),
            ("app", "glibc", "read"): edge(10, 85 * MS),
            ("app", "glibc", "write"): edge(10, 15 * MS),
        })
        dets = builtin_detectors(hot_edge={"warn_share": 0.8,
                                           "crit_share": 0.99})
        fs = run_detectors(ctx_of(t), dets)
        assert [f.severity for f in fs] == ["crit", "warn"]
        assert fs[0].detector == "wait-dominance"
        assert fs[1].detector == "hot-edge"
        again = run_detectors(ctx_of(t), dets)
        assert [f.to_json() for f in fs] == [f.to_json() for f in again]

    def test_builtin_overrides_reject_nothing_silently(self):
        """Unknown detector names AND unknown constructor params raise
        ValueError (the CLI maps it to exit 2) — a misspelled threshold
        must never be silently ignored."""
        with pytest.raises(ValueError, match="unknown parameter"):
            builtin_detectors(wait_dominance={"nope": 1})
        with pytest.raises(ValueError, match="unknown detector"):
            builtin_detectors(wait_dominanse={"warn_share": 0.5})
        # 'name' is not tunable (renaming would break the override map)
        with pytest.raises(ValueError, match="unknown parameter"):
            builtin_detectors(hot_edge={"name": "other"})


class TestDetectorConfig:
    """`diagnose --detector-config` — the file surface for detector
    constructor parameters (tune thresholds without code)."""

    @staticmethod
    def _wait_heavy(root):
        return write_ring(root, [FoldedTable({
            ("app", "runtime", "sync"): edge(10, 500 * MS, kind=KIND_WAIT),
            ("app", "runtime", "dispatch"): edge(10, 500 * MS),
        })])

    def test_load_and_apply_changes_severity(self, tmp_path):
        run = self._wait_heavy(tmp_path)
        base = diagnose(run)
        assert [f.detector for f in base.findings] == ["wait-dominance"]
        assert base.findings[0].severity == "warn"      # 50% share
        cfgf = tmp_path / "det.json"
        cfgf.write_text(json.dumps({"wait-dominance": {"crit_share": 0.4}}))
        tuned = diagnose(run, detector_config=str(cfgf))
        assert tuned.findings[0].severity == "crit"
        assert tuned.detector_config_path == str(cfgf)
        assert tuned.to_json()["detector_config"] == str(cfgf)
        relaxed = tmp_path / "relaxed.json"
        relaxed.write_text(json.dumps(
            {"wait_dominance": {"warn_share": 0.9}}))  # '_' normalizes too
        assert diagnose(run, detector_config=str(relaxed)).findings == []

    def test_structural_and_key_errors_raise_value_error(self, tmp_path):
        from repro.analysis import load_detector_config
        run = write_ring(tmp_path, [healthy_table()])
        notdict = tmp_path / "list.json"
        notdict.write_text("[1, 2]")
        with pytest.raises(ValueError, match="JSON object"):
            load_detector_config(str(notdict))
        scalar = tmp_path / "scalar.json"
        scalar.write_text(json.dumps({"wait-dominance": 0.5}))
        with pytest.raises(ValueError, match="JSON object"):
            load_detector_config(str(scalar))
        unknown = tmp_path / "unknown.json"
        unknown.write_text(json.dumps({"wait-dominance": {"bogus": 1}}))
        with pytest.raises(ValueError, match="unknown parameter"):
            diagnose(run, detector_config=str(unknown))

    def test_programmatic_overrides_win_over_file(self, tmp_path):
        run = self._wait_heavy(tmp_path)
        cfgf = tmp_path / "det.json"
        cfgf.write_text(json.dumps({"wait-dominance": {"warn_share": 0.9}}))
        d = diagnose(run, detector_config=str(cfgf),
                     overrides={"wait-dominance": {"warn_share": 0.3}})
        assert [f.detector for f in d.findings] == ["wait-dominance"]

    def test_merge_normalizes_dash_underscore_spellings(self, tmp_path):
        """A file's 'wait-dominance' and a caller's 'wait_dominance' are
        the SAME detector: their kwargs must merge key-by-key, not
        survive as two entries of which only one wins."""
        run = self._wait_heavy(tmp_path)           # 50% wait share
        cfgf = tmp_path / "det.json"
        cfgf.write_text(json.dumps({"wait-dominance": {"warn_share": 0.6}}))
        # file alone silences the 50%-share warn
        assert diagnose(run, detector_config=str(cfgf)).findings == []
        # an underscore-spelled override of a DIFFERENT param must not
        # drop the file's warn_share back to its 0.4 default
        d = diagnose(run, detector_config=str(cfgf),
                     overrides={"wait_dominance": {"crit_share": 0.95}})
        assert d.findings == []


# ----------------------------------------------------------- calibration ----
class TestCalibration:
    def test_runs_mode_bands_and_rel_threshold(self):
        thr = calibrate_runs([healthy_table() for _ in range(4)])
        key = ("app", "glibc", "read")
        b = thr.band(key, "total_ns")
        assert b.n == 4 and b.std == 0.0 and b.mean == 30 * MS
        # zero variance -> the floor, not zero tolerance
        assert thr.rel_threshold(key, "total_ns", 0.25) == 0.05
        # uncalibrated edges keep the caller's default
        assert thr.rel_threshold(("x", "y", "z"), "total_ns", 0.25) == 0.25

    def test_absent_edge_counts_as_zero_sample(self):
        a = healthy_table()
        b = healthy_table()
        extra = ("app", "ckpt", "save")
        b.edges[extra] = edge(5, 10 * MS)
        thr = calibrate_runs([a, b])
        band = thr.band(extra, "count")
        assert band.n == 2 and band.lo == 0.0 and band.hi == 5.0

    def test_ring_mode_excludes_restarts(self, tmp_path):
        run = write_ring(tmp_path, [healthy_table(3), healthy_table(1)])
        thr = calibrate_ring(build_timelines(run))
        band = thr.band(("app", "glibc", "read"), "total_ns")
        assert band.n == 1                   # the negative delta dropped

    def test_ring_mode_skips_trimmed_cumulative_head(self, tmp_path):
        """A retention-trimmed ring's first snapshot is a cumulative fold
        of the whole run so far — sampling it as one interval would blow
        the band wide open and blind the gate."""
        from repro.profile import RetentionPolicy
        store = ProfileStore(str(tmp_path),
                             retention=RetentionPolicy(keep_last=3))
        for i in range(1, 7):                # steady +1x per interval
            store.write_shard(healthy_table(i), label="t")
        [tl] = build_timelines(str(tmp_path))
        assert tl.seqs[0] != 1               # ring really was trimmed
        thr = calibrate_ring([tl])
        band = thr.band(("app", "glibc", "read"), "total_ns")
        # only the 2 true intervals sampled; a steady edge fits a ZERO
        # -variance band (sampling the seq-4 cumulative head would give
        # n=3, std>0 and a ~2x-wide tolerance)
        assert band.n == 2
        assert band.std == 0.0 and band.mean == 30 * MS

    def test_json_round_trip(self, tmp_path):
        thr = calibrate_runs([healthy_table(), healthy_table(2)],
                             meta={"who": "test"})
        p = str(tmp_path / "thr.json")
        thr.save(p)
        back = Thresholds.load(p)
        assert back.to_json() == thr.to_json()
        assert back.meta["who"] == "test"
        with pytest.raises(ValueError, match="schema"):
            Thresholds.from_json({"schema": 99})

    def test_diff_uses_calibrated_bands(self):
        base = healthy_table()
        runs = []
        for i in range(4):                   # ±10% spread around healthy
            t = healthy_table()
            for k in t.edges:
                t.edges[k].total_ns = int(
                    t.edges[k].total_ns * (0.9 + 0.2 * (i % 2)))
            runs.append(t)
        thr = calibrate_runs(runs, k_sigma=3.0)
        within = healthy_table()
        for k in within.edges:               # +15% — inside 3 sigma
            within.edges[k].total_ns = int(within.edges[k].total_ns * 1.15)
        beyond = healthy_table()
        for k in beyond.edges:               # +80% — outside any band
            beyond.edges[k].total_ns = int(beyond.edges[k].total_ns * 1.8)
        flat_fields = ("total_ns",)
        # global 10% threshold would flag the within-band candidate...
        assert diff_profiles(base, within, threshold=0.10,
                             fields=flat_fields).has_regressions
        # ...calibrated bands accept it and still catch the real one
        d_ok = diff_profiles(base, within, threshold=0.10,
                             fields=flat_fields, thresholds=thr)
        assert not d_ok.has_regressions and d_ok.calibrated
        assert diff_profiles(base, beyond, threshold=0.10,
                             fields=flat_fields,
                             thresholds=thr).has_regressions


# ------------------------------------------------------------- e2e runs ----
class TestDiagnoseEndToEnd:
    def test_pathological_run_and_fail_on(self, tmp_path):
        run = str(tmp_path / "bad")
        t = FoldedTable({
            ("app", "runtime", "dispatch"): edge(100, 100 * MS),
            ("app", "runtime", "device_sync"): edge(100, 900 * MS,
                                                    kind=KIND_WAIT),
        })
        ProfileStore(run).write_shard(t, label="train-r0")
        register_run(run, config="c", kind="train", label="train-r0")
        diag = diagnose(run)
        assert [f.detector for f in diag.findings] == ["wait-dominance"]
        assert diag.counts()["crit"] == 1
        assert diag.should_fail("crit") and diag.should_fail("warn")
        assert not diag.should_fail("none") and not diag.should_fail(None)
        assert diag.manifest["config"] == "c"
        doc = diag.to_json()
        assert doc == json.loads(json.dumps(doc))    # JSON round trip
        assert "wait-dominance" in diag.render()

    def test_registry_resolution(self, tmp_path):
        for name in ("r1", "r2"):
            run = str(tmp_path / name)
            ProfileStore(run).write_shard(healthy_table(), label=name)
            register_run(run, config="cfg", kind="train", label=name)
        d = diagnose(str(tmp_path), run="r2")
        assert d.run_dir.endswith("r2") and d.findings == []
        with pytest.raises(LookupError, match="ambiguous"):
            diagnose(str(tmp_path), run="r*")
        with pytest.raises(LookupError, match="no registered run"):
            diagnose(str(tmp_path), run="nope")
        # a run dir given directly never needs the registry
        assert diagnose(str(tmp_path / "r1")).run_dir.endswith("r1")

    def test_baseline_enables_drift_detector(self, tmp_path):
        def run_with(deltas, name):
            tables, tot = [], 0
            for d in deltas:
                tot += d
                tables.append(FoldedTable({
                    ("app", "runtime", "dispatch"): edge(1, tot)}))
            return write_ring(tmp_path / name, tables)

        base = run_with([MS, MS, MS], "base")
        cand = run_with([MS, 2 * MS, 4 * MS], "cand")
        clean = diagnose(cand)
        assert "drift-regression" not in {f.detector
                                          for f in clean.findings}
        drift = diagnose(cand, baseline=base)
        assert "drift-regression" in {f.detector for f in drift.findings}
        assert drift.baseline_dir.endswith("base")

    def test_real_trainer_run_is_deterministic(self, tmp_path):
        """Acceptance: diagnose a REAL trainer run (as in
        test_run_registry) — findings must be valid, and two diagnoses of
        the same run dir byte-identical."""
        import dataclasses

        import jax

        from repro.ckpt.manager import CheckpointManager
        from repro.configs import get_smoke
        from repro.configs.base import TrainConfig
        from repro.data.pipeline import SyntheticLMData
        from repro.models import build_model
        from repro.runtime.trainer import Trainer

        cfg = dataclasses.replace(get_smoke("tinyllama_1_1b"),
                                  n_layers=2, d_model=64, d_ff=128,
                                  vocab=512, n_heads=2, n_kv_heads=2,
                                  head_dim=32)
        model = build_model(cfg, impl="ref")
        run_dir = str(tmp_path / "run")
        trainer = Trainer(model, TrainConfig(ckpt_interval=0),
                          CheckpointManager(str(tmp_path / "ckpt")),
                          profile_dir=run_dir, profile_interval=1)
        trainer.run(jax.random.key(0), SyntheticLMData(cfg, 2, 32),
                    n_steps=3, resume=False)

        d1, d2 = diagnose(run_dir), diagnose(run_dir)
        assert json.dumps(d1.to_json(), sort_keys=True) == \
            json.dumps(d2.to_json(), sort_keys=True)
        assert d1.manifest["kind"] == "train"
        assert d1.graph_stats["rings"] >= 1
        for f in d1.findings:
            assert f.severity in ("info", "warn", "crit")
            assert f.evidence

    def test_real_serving_run_diagnoses(self, tmp_path):
        """Acceptance: a real serving run (engine + queue_depth gauge)
        flows through diagnose; the queue_wait/queue_depth edges the
        saturation detector reads are present in the graph."""
        import dataclasses

        import jax
        import numpy as np

        from repro.configs import get_smoke
        from repro.configs.base import ServeConfig
        from repro.models import build_model
        from repro.serving.engine import ServingEngine

        cfg = dataclasses.replace(get_smoke("tinyllama_1_1b"),
                                  n_layers=2, d_model=64, d_ff=128,
                                  vocab=512, n_heads=2, n_kv_heads=2,
                                  head_dim=32)
        model = build_model(cfg, impl="ref")
        run_dir = str(tmp_path / "serve-run")
        engine = ServingEngine(
            model, model.init(jax.random.key(0)),
            ServeConfig(max_batch=2, max_seq_len=64,
                        profile_dir=run_dir, profile_label="serve-0"))
        rng = np.random.default_rng(0)
        for _ in range(3):
            engine.submit(rng.integers(0, cfg.vocab, 5), 2)
        engine.run_until_drained()

        d = diagnose(run_dir)
        keys = set(d.to_json()["manifest"])          # manifest present
        assert {"config", "kind"} <= keys
        g = build_context(run_dir).graph
        assert ("serve", "serve", "queue_wait") in g.edges
        assert ("app", "serve", "queue_depth") in g.edges
        # gauge semantics: one sample per engine step, mean = depth
        depth = g.edges[("app", "serve", "queue_depth")]
        assert depth.count >= 1
        assert json.dumps(diagnose(run_dir).to_json()) == \
            json.dumps(d.to_json())
