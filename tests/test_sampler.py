"""Adaptive overhead governor (core.sampler) + the tracer bugfixes that
shipped with it.

Covers, in order:
  * SamplerController unit behaviour (stride ladder, back-off and relax
    under a fake clock, power-of-two invariants, reset);
  * the unbiased-estimator property: averaged over the k sampling
    phases, the scaled fold equals the full-trace fold EXACTLY, and
    counts are exact under ANY back-off schedule;
  * the bursty adversarial fixture from benchmarks/sampling.py — the
    workload that breaks time-based samplers (paper Table 6) must NOT
    lose the short-burst edge here, because counting never turns off;
  * mixed-rate shard merges: EdgeStats.merge and the vectorized
    merge_columns agree on count-weighted rate averaging;
  * tracer regressions: in-place reset (stale-slot misattribution),
    counting-only nested attribution, fused record_n equivalence;
  * end-to-end: governed tracer -> fold with rates -> schema-v3
    snapshot round-trip -> SamplingBackoff detector read-out.
"""

import importlib.util
import pathlib
import time

import numpy as np
import pytest

from conftest import assert_tables_equal
from repro.analysis import FlowGraph, SamplingBackoff
from repro.analysis.detectors import DiagnosisContext
from repro.core import FoldedTable, ShadowTable, Tracer
from repro.core.folding import EdgeColumns, EdgeStats, merge_columns, \
    merge_rates
from repro.core.sampler import (MIN_BRACKET_NS, SamplerController,
                                estimate_bracket_ns, fold_event)
from repro.core.shadow import SlotRegistry
from repro.profile.snapshot import ProfileSnapshot


def make_controller(budget=0.1, recalc_every=16, bracket_ns=100.0,
                    clock=None, **kw):
    """Controller with a pinned bracket cost (no calibration loop) and an
    optional fake clock (a zero-arg callable)."""
    return SamplerController(budget, recalc_every=recalc_every,
                             bracket_ns=bracket_ns,
                             clock=clock or time.perf_counter_ns, **kw)


class FakeClock:
    """Deterministic wall clock the tests advance by hand."""

    def __init__(self):
        self.now = 0

    def __call__(self):
        return self.now


# ------------------------------------------------------------ controller ----
class TestSamplerController:
    def test_budget_must_be_positive(self):
        with pytest.raises(ValueError):
            SamplerController(0.0, bracket_ns=100.0)
        with pytest.raises(ValueError):
            SamplerController(-0.2, bracket_ns=100.0)

    def test_starts_at_full_sampling(self):
        ctl = make_controller()
        assert ctl.stride(0) == 1
        # every one of the first recalc_every-1 calls is timed at scale 1
        assert all(ctl.observe(0) == 1 for _ in range(ctl.recalc_every - 1))
        assert ctl.rates() == {}          # nothing subsampled yet

    def test_backs_off_when_over_budget(self):
        clk = FakeClock()
        ctl = make_controller(budget=0.1, recalc_every=16, bracket_ns=100.0,
                              clock=clk)
        # 16 events in 160ns of wall time: estimated full-trace cost is
        # 100ns * 16 / 160ns = 10x wall; need = 10/0.1 = 100 -> stride 128
        for _ in range(16):
            clk.now += 10
            ctl.observe(0)
        assert ctl.stride(0) == 128
        # ...and the hot phase now times only every 128th call
        timed = sum(1 for _ in range(256) if ctl.observe(0) > 0)
        assert timed == 2
        assert 0 in ctl.strides()

    def test_relaxes_when_load_drops(self):
        clk = FakeClock()
        ctl = make_controller(budget=0.1, recalc_every=16, bracket_ns=100.0,
                              clock=clk)
        for _ in range(16):
            clk.now += 10
            ctl.observe(0)
        assert ctl.stride(0) > 1
        # now the edge nearly stops firing: 16 events over 16ms
        for _ in range(16):
            clk.now += 1_000_000
            ctl.observe(0)
        assert ctl.stride(0) == 1

    def test_stride_ladder_is_powers_of_two(self):
        clk = FakeClock()
        ctl = make_controller(budget=0.01, recalc_every=8, bracket_ns=200.0,
                              clock=clk)
        seen = set()
        for _ in range(64):
            clk.now += 25
            ctl.observe(0)
            seen.add(ctl.stride(0))
        for k in seen:
            assert k >= 1 and (k & (k - 1)) == 0, k

    def test_stride_respects_max(self):
        clk = FakeClock()
        ctl = make_controller(budget=1e-9, recalc_every=8, bracket_ns=1e6,
                              clock=clk, max_stride=64)
        for _ in range(32):
            clk.now += 1
            ctl.observe(0)
        assert ctl.stride(0) == 64

    def test_set_stride_validates(self):
        ctl = make_controller()
        ctl.set_stride(3, 8)
        assert ctl.stride(3) == 8
        with pytest.raises(ValueError):
            ctl.set_stride(3, 6)
        with pytest.raises(ValueError):
            ctl.set_stride(3, 0)

    def test_budget_scales_the_backoff(self):
        """Same load, double the budget -> stride no deeper."""
        strides = {}
        for budget in (0.05, 0.1, 0.2):
            clk = FakeClock()
            ctl = make_controller(budget=budget, recalc_every=16,
                                  bracket_ns=100.0, clock=clk)
            for _ in range(16):
                clk.now += 10
                ctl.observe(0)
            strides[budget] = ctl.stride(0)
        assert strides[0.05] >= strides[0.1] >= strides[0.2] > 1

    def test_rates_reflect_timed_over_seen(self):
        ctl = make_controller(recalc_every=1 << 30)   # never recalc
        ctl.set_stride(0, 4)
        for _ in range(100):
            ctl.observe(0)
        assert ctl.rates()[0] == pytest.approx(0.25)
        # a fully-timed slot stays out of the rates dict
        ctl.observe(1)
        assert 1 not in ctl.rates()

    def test_reset_preserves_slot_space(self):
        ctl = make_controller(recalc_every=1 << 30)
        ctl.set_stride(2, 8)
        for _ in range(64):
            ctl.observe(2)
        ctl.reset()
        assert ctl.rates() == {}
        assert ctl.stride(2) == 1
        assert ctl.observe(2) == 1       # slot ids survive, state zeroed

    def test_estimate_bracket_has_floor(self):
        assert estimate_bracket_ns(iters=200) >= MIN_BRACKET_NS


# ----------------------------------------------- unbiased scale-up (fold) ----
class TestUnbiasedScaleUp:
    def test_phase_average_equals_full_fold_exactly(self):
        """Sum the scaled folds over all k sampling phases and divide by
        k: integer durations make this EXACT, not approximate — each
        event is timed in exactly one phase and scaled by k there."""
        rng = np.random.default_rng(7)
        durs = rng.integers(100, 10_000, size=1000)
        full = ShadowTable()
        for d in durs:
            fold_event(full, 0, int(d), 1)
        for k in (2, 4, 8, 64):
            scaled_total = 0
            for phase in range(k):
                t = ShadowTable()
                for i, d in enumerate(durs):
                    fold_event(t, 0, int(d),
                               k if i % k == phase else 0)
                assert t.count[0] == len(durs)        # counts always exact
                scaled_total += int(t.total_ns[0])
            assert scaled_total // k == full.total_ns[0]
            assert scaled_total % k == 0

    def test_counts_exact_under_any_schedule(self):
        """Whatever stride sequence the governor walks through, count is
        the exact number of calls."""
        rng = np.random.default_rng(3)
        ctl = make_controller(recalc_every=1 << 30)
        t = ShadowTable()
        n = 5000
        for i in range(n):
            if i % 500 == 0:              # adversarial stride churn
                ctl.set_stride(0, int(2 ** rng.integers(0, 8)))
            fold_event(t, 0, 1000, ctl.observe(0))
        assert t.count[0] == n

    def test_scaled_hist_mass_matches_count(self):
        """Histogram bucket increments are scaled by k, so total hist
        mass tracks the true event count (not the sample count)."""
        t = ShadowTable()
        for i in range(1024):
            fold_event(t, 0, 500, 8 if i % 8 == 0 else 0, hist=True)
        assert t.hist is not None
        assert int(t.hist[0].sum()) == 1024

    def test_min_max_are_raw_observations(self):
        t = ShadowTable()
        t.record_scaled(0, 100, 0, 16)
        assert t.min_ns[0] == 100 and t.max_ns[0] == 100
        assert t.total_ns[0] == 1600 and t.count[0] == 1


# -------------------------------------------------------- bursty fixture ----
def _load_sampling_bench():
    path = pathlib.Path(__file__).resolve().parent.parent / \
        "benchmarks" / "sampling.py"
    spec = importlib.util.spec_from_file_location("bench_sampling", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestBurstyWorkload:
    """benchmarks/sampling.py's workload is the adversarial case the
    paper uses against samplers (Table 6): rare dense 40-call bursts of
    0.2us events hiding between 1us steady calls.  Drive it through the
    governor with the event stream's OWN timestamps as the clock."""

    def _replay(self, budget):
        bench = _load_sampling_bench()
        events = bench.synth_events(n=50_000, seed=0)
        reg = SlotRegistry()
        clk = FakeClock()
        ctl = make_controller(budget=budget, recalc_every=64,
                              bracket_ns=200.0, clock=clk)
        table = ShadowTable()
        for caller, comp, api, dur, t0 in events:
            clk.now = t0
            info = reg.resolve(caller, comp, api)
            fold_event(table, info.slot, dur, ctl.observe(info.slot))
        folded = FoldedTable.from_shadow(table, reg.infos(),
                                         rates=ctl.rates())
        truth = bench.fold_event_log(
            [(c, m, a, d) for c, m, a, d, _ in events])
        return folded, truth, ctl

    def test_burst_edge_never_lost(self):
        folded, truth, ctl = self._replay(budget=0.05)
        key = ("app", "lib", "bursty")
        assert key in folded.edges
        # the count is EXACT — this is the claim time-based sampling
        # cannot make on this workload
        assert folded.edges[key].count == truth.edges[key].count

    def test_governor_backed_off_and_totals_stay_close(self):
        folded, truth, ctl = self._replay(budget=0.05)
        assert ctl.rates(), "tight budget must engage back-off"
        for key in (("app", "lib", "steady"), ("app", "lib", "bursty")):
            est, true = folded.edges[key], truth.edges[key]
            assert est.count == true.count
            # scaled totals are estimates; near-constant durations keep
            # them within a few percent of ground truth
            assert est.total_ns == pytest.approx(true.total_ns, rel=0.05)

    def test_bursty_share_preserved(self):
        """The headline Table 6 failure is the bursty API's *share*
        collapsing under sampling; the governed fold keeps it."""
        folded, truth, _ = self._replay(budget=0.05)
        def share(f):
            return f.edges[("app", "lib", "bursty")].total_ns / f.total_ns()
        assert share(folded) == pytest.approx(share(truth), rel=0.10)


# ---------------------------------------------------- mixed-rate merging ----
class TestRateMerge:
    def test_merge_rates_helper(self):
        assert merge_rates(None, 10, None, 20) is None
        assert merge_rates(0.5, 10, None, 10) == pytest.approx(0.75)
        assert merge_rates(0.25, 30, 0.75, 10) == pytest.approx(0.375)
        assert merge_rates(None, 0, None, 0) is None
        assert merge_rates(1.0, 5, None, 5) is None      # >= 1 normalizes

    def test_edgestats_merge_weighs_by_count(self):
        a = EdgeStats(count=30, total_ns=3000, sample_rate=0.25)
        b = EdgeStats(count=10, total_ns=1000, sample_rate=0.75)
        m = a.merge(b)
        assert m.count == 40
        assert m.sample_rate == pytest.approx((0.25 * 30 + 0.75 * 10) / 40)

    def test_merge_columns_agrees_with_edgestats(self):
        """The vectorized shard merge and the per-edge object merge are
        the same algebra."""
        key = ("app", "lib", "x")
        fa = FoldedTable()
        fa.edges[key] = EdgeStats(count=300, total_ns=9000, min_ns=10,
                                  max_ns=50, sample_rate=0.125)
        fb = FoldedTable()
        fb.edges[key] = EdgeStats(count=100, total_ns=4000, min_ns=5,
                                  max_ns=80)                 # fully sampled
        merged_cols = merge_columns([fa.to_columns(), fb.to_columns()])
        merged_obj = fa.merge(fb)
        assert_tables_equal(merged_cols.to_folded(), merged_obj)
        got = merged_obj.edges[key].sample_rate
        assert got == pytest.approx((0.125 * 300 + 1.0 * 100) / 400)

    def test_rateless_merge_stays_rateless(self):
        fa = FoldedTable()
        fa.edges[("app", "l", "x")] = EdgeStats(count=3, total_ns=30)
        fb = FoldedTable()
        fb.edges[("app", "l", "x")] = EdgeStats(count=2, total_ns=20)
        merged = merge_columns([fa.to_columns(), fb.to_columns()])
        assert merged.sample_rate is None
        assert merged.to_folded().edges[("app", "l", "x")].sample_rate is None


# ------------------------------------------------------ tracer bugfixes ----
class TestTracerReset:
    def test_reset_keeps_cached_slots_attributed(self):
        """Regression: reset() used to swap in a fresh ShadowTableSet,
        but @api wrappers cache SlotInfos from the OLD registry — every
        post-reset call then recorded at indices the new registry handed
        to different edges.  Reset must zero in place."""
        t = Tracer()

        @t.api("liba")
        def f():
            return 1

        f()
        t.reset()
        # a new edge interned after the reset must not collide with f's
        # cached pre-reset slot
        with t.scope("data", "load"):
            f()
        f()
        folds = FoldedTable.merge_all(FoldedTable.from_set(t.tables))
        assert folds.edges[("app", "liba", "f")].count == 1
        assert folds.edges[("data", "liba", "f")].count == 1
        assert folds.edges[("app", "data", "load")].count == 1

    def test_reset_clears_governor_state(self):
        t = Tracer()
        ctl = t.set_overhead_budget(0.1, bracket_ns=100.0)
        ctl.set_stride(0, 8)

        @t.api("liba")
        def f():
            return 1

        for _ in range(32):
            f()
        assert t.sample_rates()
        t.reset()
        assert t.sample_rates() == {}
        f()
        folds = FoldedTable.merge_all(FoldedTable.from_set(t.tables))
        assert folds.edges[("app", "liba", "f")].count == 1


class TestCountingModeAttribution:
    def test_nested_boundaries_keep_true_caller(self):
        """Regression: timing=False skipped the frame push, so nested
        boundaries all folded with caller 'app' instead of their real
        calling component."""
        t = Tracer()
        t.timing = False

        @t.api("liba")
        def inner():
            return 1

        @t.api("libb")
        def outer():
            return inner()

        for _ in range(3):
            outer()
        folds = FoldedTable.merge_all(FoldedTable.from_set(t.tables))
        e = folds.edges[("libb", "liba", "inner")]
        assert e.count == 3 and e.total_ns == 0
        assert ("app", "liba", "inner") not in folds.edges
        assert folds.edges[("app", "libb", "outer")].count == 3

    def test_sampled_out_calls_keep_true_caller(self):
        """Same property when the governor (not the timing switch) drops
        the bracket: sampled-out outer calls still push a lightweight
        frame, so inner attribution never degrades to 'app'."""
        t = Tracer()
        ctl = t.set_overhead_budget(0.5, recalc_every=1 << 30,
                                    bracket_ns=100.0)

        @t.api("liba")
        def inner():
            return 1

        @t.api("libb")
        def outer():
            return inner()

        outer()   # interns both slots (outer=0, inner=1)
        ctl.set_stride(0, 1 << 15)    # outer: practically never timed
        for _ in range(63):
            outer()
        assert t.stack_depth() == 0
        folds = FoldedTable.merge_all(FoldedTable.from_set(t.tables))
        assert folds.edges[("libb", "liba", "inner")].count == 64
        assert ("app", "liba", "inner") not in folds.edges

    def test_exception_pops_lightweight_frame(self):
        t = Tracer()
        t.timing = False

        @t.api("liba")
        def boom():
            raise RuntimeError("x")

        with pytest.raises(RuntimeError):
            boom()
        assert t.stack_depth() == 0


class TestRecordNFused:
    def test_record_n_equals_n_records(self):
        a, b = ShadowTable(), ShadowTable()
        a.record_n(3, 250, 7)
        for _ in range(7):
            b.record(3, 250, 0)
        for col in ("count", "total_ns", "child_ns", "min_ns", "max_ns"):
            assert getattr(a, col)[3] == getattr(b, col)[3], col

    def test_record_duration_bulk_equals_loop(self):
        """The pooled serving tick folds n per-token latencies in one
        fused call; it must be indistinguishable from the old O(n)
        loop."""
        ta, tb = Tracer(), Tracer()
        ta.record_duration("serve", "decode_token", 800, n=5)
        for _ in range(5):
            tb.record_duration("serve", "decode_token", 800, n=1)
        fa = FoldedTable.merge_all(FoldedTable.from_set(ta.tables))
        fb = FoldedTable.merge_all(FoldedTable.from_set(tb.tables))
        assert_tables_equal(fa, fb)

    def test_record_n_zero_is_noop(self):
        t = ShadowTable()
        t.record_n(0, 100, 0)
        assert t.count[0] == 0 and t.min_ns[0] == np.iinfo(np.int64).max


# ------------------------------------------------------------ end-to-end ----
class TestGovernedPipeline:
    def _governed_fold(self):
        t = Tracer()
        ctl = t.set_overhead_budget(0.1, recalc_every=1 << 30,
                                    bracket_ns=100.0)

        @t.api("liba")
        def f():
            return 1

        f()                      # interns slot 0
        ctl.set_stride(0, 4)
        for _ in range(127):
            f()
        return FoldedTable.from_set(t.tables, rates=t.sample_rates())

    def test_fold_carries_effective_rate(self):
        folds = FoldedTable.merge_all(self._governed_fold())
        e = folds.edges[("app", "liba", "f")]
        assert e.count == 128
        assert e.sample_rate is not None and e.sample_rate < 1.0
        assert e.effective_rate == e.sample_rate

    def test_snapshot_roundtrip_preserves_rates(self, tmp_path):
        folds = FoldedTable.merge_all(self._governed_fold())
        snap = ProfileSnapshot.from_folded(folds, meta={"run": "governed"})
        p = tmp_path / "governed.xfa.npz"
        snap.save(str(p))
        loaded = ProfileSnapshot.load(str(p))
        assert loaded.schema == 3
        assert_tables_equal(loaded.columns.to_folded(), folds)

    def test_backoff_detector_reads_rates(self):
        folds = FoldedTable.merge_all(self._governed_fold())
        ctx = DiagnosisContext(graph=FlowGraph.from_folded(folds))
        findings = SamplingBackoff().detect(ctx)
        assert len(findings) == 1
        f = findings[0]
        assert f.severity == "info" and f.detector == "sampling-backoff"
        assert f.evidence["count"] == 128
        assert 0 < f.evidence["sample_rate"] < 1.0

    def test_ungoverned_fold_emits_no_findings(self):
        t = Tracer()

        @t.api("liba")
        def f():
            return 1

        f()
        folds = FoldedTable.merge_all(
            FoldedTable.from_set(t.tables, rates=t.sample_rates()))
        ctx = DiagnosisContext(graph=FlowGraph.from_folded(folds))
        assert SamplingBackoff().detect(ctx) == []
