"""Diagnosis orchestration: run selection -> context -> findings -> report.

This is the layer behind `python -m repro.profile diagnose` — it resolves
what to analyze (a run dir, or a registry root plus `--run` pattern),
assembles the DiagnosisContext from everything the profile store knows
(merged reduce, per-shard newest snapshots, snapshot rings, an optional
baseline run and calibrated thresholds), runs the detector set, and
renders the findings as deterministic text or JSON with CI-composable
exit semantics (`--fail-on warn|crit`).
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

from .calibrate import Thresholds
from .detectors import (SEVERITIES, Detector, DiagnosisContext, Finding,
                        builtin_detectors, run_detectors, severity_rank)
from .graph import run_graph, shard_graphs


def _is_run_dir(path: str) -> bool:
    from ..profile.store import ProfileStore
    return os.path.isdir(path) and bool(ProfileStore(path).snapshot_paths())


def resolve_run_dir(root: str, run: Optional[str] = None) -> str:
    """Resolve what `diagnose ROOT [--run PATTERN]` points at.

    ROOT that directly holds snapshots is the run dir (PATTERN must then
    be absent).  Otherwise ROOT is a registry root and PATTERN selects by
    run id / label / config glob via RunRegistry.find — ambiguity is an
    error that lists the candidates, never a silent first-match."""
    if _is_run_dir(root):
        if run:
            raise LookupError(
                f"{root!r} is itself a run dir; --run {run!r} does not "
                f"apply (point ROOT at the registry root instead)")
        return root
    from ..profile.index import RunRegistry
    return RunRegistry(root).find(run)


def load_baseline(spec: str, root: str) -> str:
    """A baseline can be a run dir path, or a run id/label/config pattern
    resolved against the same registry root."""
    if _is_run_dir(spec):
        return spec
    if os.path.isdir(root) and not os.path.isdir(spec):
        from ..profile.index import RunRegistry
        return RunRegistry(root).find(spec)
    raise LookupError(f"baseline {spec!r}: not a run dir and no registry "
                      f"match under {root!r}")


def load_detector_config(path: str) -> Dict[str, Dict]:
    """Parse a `--detector-config` JSON file: a top-level object mapping
    detector names ('-' or '_' accepted) to constructor-parameter objects,
    e.g. {"wait-dominance": {"warn_share": 0.5}}.

    This is the file surface for tuning detector thresholds without code:
    the result feeds builtin_detectors(**overrides), which rejects unknown
    detector names and unknown parameters (ValueError -> the CLI exits 2,
    same contract as a corrupt --thresholds file)."""
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) \
            or not all(isinstance(v, dict) for v in data.values()):
        raise ValueError(
            f"detector config {path!r} must be a JSON object mapping "
            f"detector names to parameter objects")
    return data


def build_context(run_dir: str, *, baseline_dir: Optional[str] = None,
                  thresholds: Optional[Thresholds] = None
                  ) -> DiagnosisContext:
    """Assemble everything the detectors read for one run."""
    from ..profile.timeline import build_timelines
    ctx = DiagnosisContext(
        graph=run_graph(run_dir),
        shard_graphs=shard_graphs(run_dir),
        timelines=build_timelines(run_dir),
        thresholds=thresholds,
        run_dir=os.path.abspath(run_dir))
    if baseline_dir:
        ctx.baseline_graph = run_graph(baseline_dir)
        ctx.baseline_timelines = build_timelines(baseline_dir)
    return ctx


@dataclass
class Diagnosis:
    """The result object: findings + enough context to render/gate."""

    run_dir: str
    findings: List[Finding]
    detectors: List[str]
    graph_stats: Dict[str, int] = field(default_factory=dict)
    manifest: Dict[str, Any] = field(default_factory=dict)
    baseline_dir: Optional[str] = None
    thresholds_path: Optional[str] = None
    detector_config_path: Optional[str] = None

    def counts(self) -> Dict[str, int]:
        c = {s: 0 for s in SEVERITIES}
        for f in self.findings:
            c[f.severity] += 1
        return c

    def worst(self) -> Optional[str]:
        return max((f.severity for f in self.findings),
                   key=severity_rank, default=None)

    def should_fail(self, fail_on: Optional[str]) -> bool:
        """True when any finding is at/above `fail_on` ('warn'|'crit')."""
        if not fail_on or fail_on == "none":
            return False
        bar = severity_rank(fail_on)
        return any(severity_rank(f.severity) >= bar for f in self.findings)

    def to_json(self) -> dict:
        return {
            "run_dir": self.run_dir,
            "baseline_dir": self.baseline_dir,
            "thresholds": self.thresholds_path,
            "detector_config": self.detector_config_path,
            "detectors": list(self.detectors),
            "graph": dict(self.graph_stats),
            "manifest": self.manifest,
            "counts": self.counts(),
            "findings": [f.to_json() for f in self.findings],
        }

    def render(self, top: int = 50) -> str:
        c = self.counts()
        what = self.manifest
        desc = ""
        if what:
            desc = (f" (config={what.get('config') or '-'} "
                    f"kind={what.get('kind') or '-'})")
        g = self.graph_stats
        lines = [
            f"diagnosis: {self.run_dir}{desc}",
            f"  graph: {g.get('components', 0)} components, "
            f"{g.get('edges', 0)} edges, {g.get('shards', 0)} shard(s), "
            f"{g.get('rings', 0)} ring(s); "
            f"{len(self.detectors)} detectors"
            + (f"; baseline: {self.baseline_dir}" if self.baseline_dir
               else "")
            + (f"; thresholds: {self.thresholds_path}"
               if self.thresholds_path else "")
            + (f"; detector-config: {self.detector_config_path}"
               if self.detector_config_path else ""),
            f"  findings: {c['crit']} crit, {c['warn']} warn, "
            f"{c['info']} info",
        ]
        for f in self.findings[:top]:
            lines.append(f"  [{f.severity.upper():4s}] {f.detector}: "
                         f"{f.message}")
        if len(self.findings) > top:
            lines.append(f"  ... ({len(self.findings) - top} more)")
        if not self.findings:
            lines.append("  no findings — profile looks healthy to every "
                         "detector")
        return "\n".join(lines)


def diagnose(root: str, *, run: Optional[str] = None,
             baseline: Optional[str] = None,
             thresholds_path: Optional[str] = None,
             detectors: Optional[Sequence[Detector]] = None,
             overrides: Optional[Dict[str, Dict]] = None,
             detector_config: Optional[str] = None) -> Diagnosis:
    """End-to-end diagnosis of one run (the CLI body, importable).

    detector_config: path to a JSON file of per-detector constructor
    parameters (see load_detector_config); programmatic `overrides` win
    over file values key-by-key."""
    run_dir = resolve_run_dir(root, run)
    baseline_dir = load_baseline(baseline, root) if baseline else None
    thr = Thresholds.load(thresholds_path) if thresholds_path else None
    ctx = build_context(run_dir, baseline_dir=baseline_dir, thresholds=thr)
    # normalize '-'/'_' spellings BEFORE merging: keyed raw, a file's
    # "wait-dominance" and a caller's "wait_dominance" would survive as
    # two entries and builtin_detectors' own normalization would keep
    # only one of them, silently dropping the other's values
    norm = lambda k: k.replace("_", "-")
    over: Dict[str, Dict] = {}
    if detector_config:
        over.update({norm(k): dict(v)
                     for k, v in load_detector_config(detector_config).items()})
    for name, kwargs in (overrides or {}).items():
        merged = dict(over.get(norm(name), {}))
        merged.update(kwargs)
        over[norm(name)] = merged
    dets = list(detectors) if detectors is not None \
        else builtin_detectors(**over)
    findings = run_detectors(ctx, dets)
    manifest: Dict[str, Any] = {}
    try:
        from ..profile.index import RunManifest
        manifest = RunManifest.load(run_dir).to_json()
    except (FileNotFoundError, json.JSONDecodeError, ValueError):
        pass                       # unregistered dirs still diagnose
    return Diagnosis(
        run_dir=os.path.abspath(run_dir),
        findings=findings,
        detectors=[d.name for d in dets],
        graph_stats={"components": len(ctx.graph.nodes),
                     "edges": len(ctx.graph.edges),
                     "shards": len(ctx.shard_graphs),
                     "rings": len(ctx.timelines)},
        manifest=manifest,
        baseline_dir=os.path.abspath(baseline_dir) if baseline_dir else None,
        thresholds_path=thresholds_path,
        detector_config_path=detector_config)
