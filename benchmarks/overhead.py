"""Paper Table 1/3 analogue: runtime overhead of full-trace XFA.

Scaler claims 20.3% runtime overhead for 100% API-invocation tracing. Our
three layers are measured separately on a real (CPU) training loop:

  baseline     XFA fully disabled
  host         L1 host tracer on every framework boundary
  host+device  L1 + L2 in-graph fold table threaded through the step

The paper's bar is ~20%; the in-graph fold should be far cheaper because the
fold rides inside the compiled step (a few scalar adds vs 1e9-FLOP matmuls).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.configs.base import TrainConfig
from repro.core import tracer as xfa
from repro.data.pipeline import SyntheticLMData
from repro.models import build_model
from repro.optim import adamw
from repro.runtime.trainer import init_train_state, make_train_step


def _loop(model, tcfg, steps, with_host, with_device, data):
    xfa.reset()
    xfa.set_enabled(with_host)
    try:
        step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
        state = init_train_state(model, jax.random.key(0), tcfg)
        table = model.table()
        batch = {k: jnp.asarray(v) for k, v in data.generate(0).items()}
        state, m, table = step_fn(state, batch, table)   # compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter_ns()
        for i in range(steps):
            if with_host:
                with xfa.scope("runtime", "dispatch_step"):
                    state, m, table = step_fn(state, batch, table)
                with xfa.scope("runtime", "device_sync", xfa.KIND_WAIT):
                    jax.block_until_ready(m["loss"])
            else:
                state, m, table = step_fn(state, batch, table)
                jax.block_until_ready(m["loss"])
        return (time.perf_counter_ns() - t0) / steps
    finally:
        xfa.set_enabled(True)


def run(steps: int = 8):
    # an arch with live device-fold traffic (MoE emits expert loads)
    model_nofold = build_model(get_smoke("phi3_5_moe_42b"), impl="ref")
    tcfg = TrainConfig(microbatches=1, ckpt_interval=0)
    data = SyntheticLMData(model_nofold.cfg, 4, 64)

    # device-fold OFF: rebuild with fold_spec stripped
    import dataclasses
    model_off = dataclasses.replace(
        model_nofold, rt=dataclasses.replace(model_nofold.rt,
                                             fold_spec=None))
    base = _loop(model_off, tcfg, steps, False, False, data)
    host = _loop(model_off, tcfg, steps, True, False, data)
    full = _loop(model_nofold, tcfg, steps, True, True, data)

    rows = [
        ("overhead.baseline_step_us", base / 1e3, ""),
        ("overhead.host_pct", 100 * (host - base) / base,
         "paper Scaler: 20.3%"),
        ("overhead.host_device_pct", 100 * (full - base) / base,
         "full trace incl. in-graph fold"),
    ]
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.2f},{note}")
