from .adamw import (apply_updates, compress_grads_with_feedback,
                    dequantize_int8, global_norm, init_error_state,
                    init_state, quantize_int8, warmup_cosine)
