"""Workload drivers + latency aggregation shared by the serve launcher
and benchmarks.

Two canonical ways to load a serving engine:

  * closed-loop — submit everything up front, drain synchronously: a
    throughput measurement (queue wait is dominated by the backlog).
  * open-loop — Poisson arrivals against the engine's background thread:
    the latency-under-load measurement (TTFT and queue wait reflect an
    arrival process, not a backlog artifact).

Keeping the drive loop and the stats math in ONE place means the
launcher's human summary and the benchmark's CSV can never drift apart.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from .engine import Request, ServingEngine
from .sampling import SamplingParams


def run_workload(engine: ServingEngine, prompts: Sequence[np.ndarray],
                 max_new_tokens: int, mode: str = "closed",
                 rate: float = 4.0, rng: Optional[np.random.Generator] = None,
                 sampling: Optional[SamplingParams] = None) -> List[Request]:
    """Drive `engine` with `prompts` and drain; returns completed requests.

    mode='open' starts the background thread and spaces submissions by
    exponential inter-arrival times (mean 1/rate seconds)."""
    if mode not in ("open", "closed"):
        raise ValueError(f"unknown workload mode {mode!r}")
    if mode == "open":
        rng = rng or np.random.default_rng(0)
        engine.start()
        for p in prompts:
            engine.submit(p, max_new_tokens, sampling=sampling)
            time.sleep(float(rng.exponential(1.0 / max(rate, 1e-6))))
        done = engine.run_until_drained()
        engine.stop()
        return done
    for p in prompts:
        engine.submit(p, max_new_tokens, sampling=sampling)
    return engine.run_until_drained()


def latency_stats(done: Sequence[Request], wall_s: float) -> Dict[str, float]:
    """Aggregate a drained run into the canonical serve metrics (seconds)."""
    tokens = sum(len(r.output) for r in done)
    out: Dict[str, float] = {
        "requests": float(len(done)),
        "tokens": float(tokens),
        "wall_s": wall_s,
        "throughput_tok_s": tokens / wall_s if wall_s > 0 else 0.0,
        "truncated": float(sum(1 for r in done if r.truncated)),
    }
    ttft = [r.ttft_s for r in done if r.ttft_s is not None]
    qw = [r.queue_wait_s for r in done if r.queue_wait_s is not None]
    decode = [(r.finished_at - r.first_token_at) / max(len(r.output) - 1, 1)
              for r in done
              if r.finished_at is not None and r.first_token_at is not None]
    for name, xs in (("ttft", ttft), ("queue_wait", qw)):
        if xs:
            out[f"{name}_mean_s"] = float(np.mean(xs))
            out[f"{name}_p50_s"] = float(np.percentile(xs, 50))
            out[f"{name}_p95_s"] = float(np.percentile(xs, 95))
    if decode:
        out["decode_s_per_tok"] = float(np.mean(decode))
    return out
