"""Mamba2 (SSD) blocks + the Zamba2 hybrid backbone.

Mamba2 block [arXiv:2405.21060]: in_proj -> (z, x, B, C, dt); causal
depthwise conv over (x, B, C); silu; SSD scan (Pallas kernel on TPU, chunked
jnp oracle on CPU — kernels/ops.ssd_scan); D skip; silu(z) gate; group
RMSNorm; out_proj.

Zamba2 [arXiv:2411.15242]: a stack of Mamba2 layers with ONE weight-tied
attention(+MLP) block applied every `attn_every` layers. The shared block's
params are closed over (not scanned); the Mamba stack is scanned as
[n_super, attn_every, ...]. DESIGN.md records the simplification vs the
published model (single shared block, per-invocation LoRA omitted).

Decode state is O(1) in sequence length: conv tail [B, K-1, ch] + SSD state
h [B, H, N, P] per layer; the shared attention block keeps a standard KV
cache per invocation ([n_super, B, Hkv, S, hd]) — for long_500k that cache is
what gets sequence-sharded (context parallelism).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.device_fold import DeviceFoldSpec, annotate_cost, scan_multiplier
from repro.kernels import ops
from repro.parallel.axes import shard

from .layers import (Params, Runtime, _init, attention, cross_entropy, embed,
                     init_attention, init_embed, init_lm_head, init_mlp,
                     init_norm, lm_head, linear, mlp, norm, pdtype)


# ------------------------------------------------------------ mamba block ----
def init_mamba_block(key, cfg: ModelConfig) -> Params:
    d, di, n = cfg.d_model, cfg.d_inner_, cfg.ssm_state
    heads = cfg.n_ssm_heads
    ks = jax.random.split(key, 4)
    dt = pdtype(cfg)
    conv_ch = di + 2 * n
    p = {
        "in_proj": _init(ks[0], (d, 2 * di + 2 * n + heads), dt),
        "conv_w": _init(ks[1], (cfg.conv_kernel, conv_ch), dt,
                        scale=cfg.conv_kernel ** -0.5),
        "out_proj": _init(ks[2], (di, d), dt),
        "a_log": jnp.zeros((heads,), jnp.float32),       # A = -exp(a_log)
        "dt_bias": jnp.full((heads,), -2.0, jnp.float32),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "norm": jnp.ones((di,), dt),
    }
    return {"norm1": init_norm(cfg), "ssm": p}


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv. x: [B, L, ch], w: [K, ch].
    state: [B, K-1, ch] tail of previous tokens (decode). Returns (y, new
    state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)               # [B, L+K-1, ch]
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    new_state = xp[:, -(K - 1):]
    return y, new_state


def mamba_block(p: Params, x: jax.Array, rt: Runtime,
                state: Optional[Params] = None, return_state: bool = False):
    """x: [B, L, d] -> (y, new_state).

    state None = full-sequence mode (training/prefill); return_state=True
    additionally materializes the post-sequence (conv tail, SSD h) state so
    prefill can hand off to decode."""
    cfg = rt.cfg
    sp = p["ssm"]
    B, L, d = x.shape
    di, n, heads = cfg.d_inner_, cfg.ssm_state, cfg.n_ssm_heads
    ph = cfg.ssm_head_dim
    with jax.named_scope("ssm"):
        h = norm(p["norm1"], x, rt)
        proj = linear(sp["in_proj"], h)
        z = proj[..., :di]
        xbc = proj[..., di:di + di + 2 * n]
        dt_raw = proj[..., -heads:]
        annotate_cost("ssm", "ssm", "in_proj",
                      flops=2.0 * B * L * d * (2 * di + 2 * n + heads))

        conv_state = state["conv"] if state is not None else None
        xbc, new_conv = _causal_conv(xbc, sp["conv_w"].astype(x.dtype),
                                     conv_state)
        xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
        xs = xbc[..., :di].reshape(B, L, heads, ph)
        b_mat = xbc[..., di:di + n]
        c_mat = xbc[..., di + n:]

        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + sp["dt_bias"][None, None])
        a = -jnp.exp(sp["a_log"])

        if state is None:
            y, h_final = ops.ssd_scan(xs, dt, a, b_mat, c_mat,
                                      chunk=min(cfg.ssm_chunk, L),
                                      impl=rt.impl)
            new_ssm = h_final
            if return_state:
                # conv tail must be the PRE-silu raw conv inputs
                raw_tail = proj[..., di:di + di + 2 * n][:, -(cfg.conv_kernel - 1):]
                conv_tail = raw_tail
        else:
            # single-step recurrence (decode): L == 1
            h_prev = state["h"]                           # [B, H, N, P] f32
            dt1 = dt[:, 0]                                # [B, H]
            decay = jnp.exp(a[None] * dt1)                # [B, H]
            dbx = jnp.einsum("bh,bn,bhp->bhnp", dt1,
                             b_mat[:, 0].astype(jnp.float32),
                             xs[:, 0].astype(jnp.float32))
            h_new = decay[..., None, None] * h_prev + dbx
            y = jnp.einsum("bn,bhnp->bhp", c_mat[:, 0].astype(jnp.float32),
                           h_new)[:, None].astype(x.dtype)
            new_ssm = h_new
            y = y.reshape(B, 1, heads, ph)

        y = y.astype(jnp.float32) + sp["d_skip"][None, None, :, None] \
            * xs.astype(jnp.float32)
        y = y.reshape(B, L, di)
        y = y * jax.nn.silu(z.astype(jnp.float32))
        y = ops.rmsnorm(y.astype(x.dtype), sp["norm"], eps=cfg.norm_eps,
                        impl=rt.impl)
        out = linear(sp["out_proj"], y)
        annotate_cost("ssm", "ssm", "out_proj", flops=2.0 * B * L * di * d)
        if state is not None:
            new_state = {"conv": new_conv.astype(state["conv"].dtype),
                         "h": new_ssm}
        elif return_state:
            new_state = {"conv": conv_tail, "h": new_ssm}
        else:
            new_state = None
        return shard(out, "batch", "seq", None), new_state


def init_mamba_state(cfg: ModelConfig, batch: int, n_layers: int,
                     dtype=jnp.float32) -> Params:
    di, n = cfg.d_inner_, cfg.ssm_state
    heads, ph = cfg.n_ssm_heads, cfg.ssm_head_dim
    conv_ch = di + 2 * n
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.conv_kernel - 1, conv_ch),
                          dtype),
        "h": jnp.zeros((n_layers, batch, heads, n, ph), jnp.float32),
    }


# ---------------------------------------------------------- zamba2 hybrid ----
def init_params(key, cfg: ModelConfig) -> Params:
    """Zamba2: scanned mamba stack [n_super, attn_every, ...] + ONE shared
    attention/MLP block."""
    assert cfg.attn_every > 0
    n_super = cfg.n_layers // cfg.attn_every
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {}
    p.update(init_embed(ks[0], cfg))
    p.update(init_lm_head(ks[1], cfg))
    p["final_norm"] = init_norm(cfg)
    lkeys = jax.random.split(ks[2], cfg.n_layers).reshape(
        n_super, cfg.attn_every)
    stack = jax.vmap(jax.vmap(
        functools.partial(init_mamba_block, cfg=cfg)))(lkeys)
    p["stack"] = {"stack": stack}
    shared: Dict[str, Any] = {"norm1": init_norm(cfg), "norm2": init_norm(cfg)}
    shared.update(init_attention(ks[3], cfg))
    shared.update(init_mlp(ks[4], cfg))
    p["shared_attn"] = shared
    return p


def _shared_block(shared: Params, x: jax.Array, rt: Runtime,
                  positions: jax.Array, cache=None, pos=None):
    h = norm(shared["norm1"], x, rt)
    a, new_cache = attention(shared, h, rt, positions, cache=cache, pos=pos)
    x = x + a
    h = norm(shared["norm2"], x, rt)
    x = x + mlp(shared, h, rt)
    return x, new_cache


def forward(p: Params, tokens: jax.Array, rt: Runtime, table: jax.Array,
            prefix_embeds=None):
    cfg = rt.cfg
    n_super = cfg.n_layers // cfg.attn_every
    x = embed(p, tokens, rt)
    S = x.shape[1]
    positions = jnp.arange(S)
    shared = p["shared_attn"]

    def super_body(carry, super_p):
        x, table = carry

        def inner(carry2, layer_p):
            x2, = carry2
            y, _ = mamba_block(layer_p, x2, rt)
            return (x2 + y,), None

        with scan_multiplier(cfg.attn_every):
            (x,), _ = jax.lax.scan(inner, (x,), super_p)
        x, _ = _shared_block(shared, x, rt, positions)
        return (x, table), None

    if cfg.remat != "none":
        super_body = jax.checkpoint(
            super_body, policy=jax.checkpoint_policies.dots_saveable
            if cfg.remat == "dots_saveable" else None)
    with scan_multiplier(n_super):
        (x, table), _ = jax.lax.scan(super_body, (x, table),
                                     p["stack"]["stack"])
    x = norm(p["final_norm"], x, rt)
    return x, table, jnp.float32(0.0)


def loss_fn(p: Params, batch, rt: Runtime, table: jax.Array):
    x, table, aux = forward(p, batch["tokens"], rt, table)
    logits = lm_head(p, x, rt)
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss + aux, ({"loss": loss, "aux_loss": aux}, table)


# -------------------------------------------------------------- serving ----
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    n_super = cfg.n_layers // cfg.attn_every
    hd = cfg.head_dim_
    return {
        "ssm": init_mamba_state(cfg, batch, cfg.n_layers, dtype),
        "attn_k": jnp.zeros((n_super, batch, cfg.n_kv_heads, max_len, hd),
                            dtype),
        "attn_v": jnp.zeros((n_super, batch, cfg.n_kv_heads, max_len, hd),
                            dtype),
    }


def prefill(p: Params, tokens: jax.Array, rt: Runtime, table: jax.Array,
            cache: Params, prefix_embeds=None):
    cfg = rt.cfg
    n_super = cfg.n_layers // cfg.attn_every
    k = cfg.attn_every
    x = embed(p, tokens, rt)
    S = x.shape[1]
    positions = jnp.arange(S)
    shared = p["shared_attn"]
    ssm0 = jax.tree.map(
        lambda a: a.reshape((n_super, k) + a.shape[1:]), cache["ssm"])

    def super_body(carry, inp):
        x, table = carry
        super_p, ssm_seg = inp

        def inner(carry2, inp2):
            x2, = carry2
            layer_p, st = inp2
            y, new_st = mamba_block(layer_p, x2, rt, return_state=True)
            new_st = {"conv": new_st["conv"].astype(st["conv"].dtype),
                      "h": new_st["h"]}
            return (x2 + y,), new_st

        with scan_multiplier(k):
            (x,), new_seg = jax.lax.scan(inner, (x,), (super_p, ssm_seg))
        h2 = norm(shared["norm1"], x, rt)
        a, kv = attention(shared, h2, rt, positions, return_kv=True)
        x = x + a
        h2 = norm(shared["norm2"], x, rt)
        x = x + mlp(shared, h2, rt)
        return (x, table), (new_seg, kv)

    with scan_multiplier(n_super):
        (x, table), (new_ssm, kvs) = jax.lax.scan(
            super_body, (x, table), (p["stack"]["stack"], ssm0))

    x = norm(p["final_norm"], x, rt)
    logits = lm_head(p, x[:, -1:], rt)[:, 0]
    ck = jax.lax.dynamic_update_slice(
        cache["attn_k"], kvs["k"].astype(cache["attn_k"].dtype),
        (0, 0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["attn_v"], kvs["v"].astype(cache["attn_v"].dtype),
        (0, 0, 0, 0, 0))
    new_cache = {
        "ssm": jax.tree.map(
            lambda a: a.reshape((n_super * k,) + a.shape[2:]), new_ssm),
        "attn_k": ck, "attn_v": cv,
    }
    return logits, new_cache, table


def decode_step(p: Params, token: jax.Array, rt: Runtime, table: jax.Array,
                cache: Params, pos: jax.Array):
    """pos: [B] per-slot depths (scalar broadcasts) — the shared attention
    block's KV writes/masks and rope angles are per-row; the SSM states
    are position-free and row-independent by construction."""
    cfg = rt.cfg
    n_super = cfg.n_layers // cfg.attn_every
    k = cfg.attn_every
    x = embed(p, token[:, None], rt)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), token.shape)
    positions = pos[:, None]                     # [B, 1] per-row rope angles
    shared = p["shared_attn"]
    ssm0 = jax.tree.map(
        lambda a: a.reshape((n_super, k) + a.shape[1:]), cache["ssm"])

    def super_body(carry, inp):
        x, table = carry
        super_p, ssm_seg, kc, vc = inp

        def inner(carry2, inp2):
            x2, = carry2
            layer_p, st = inp2
            y, new_st = mamba_block(layer_p, x2, rt, state=st)
            return (x2 + y,), new_st

        with scan_multiplier(k):
            (x,), new_seg = jax.lax.scan(inner, (x,), (super_p, ssm_seg))
        x, new_kv = _shared_block(shared, x, rt, positions,
                                  cache={"k": kc, "v": vc}, pos=pos)
        return (x, table), (new_seg, new_kv["k"], new_kv["v"])

    with scan_multiplier(n_super):
        (x, table), (new_ssm, nk, nv) = jax.lax.scan(
            super_body, (x, table),
            (p["stack"]["stack"], ssm0, cache["attn_k"], cache["attn_v"]))

    x = norm(p["final_norm"], x, rt)
    logits = lm_head(p, x, rt)[:, 0]
    new_cache = {
        "ssm": jax.tree.map(
            lambda a: a.reshape((n_super * k,) + a.shape[2:]), new_ssm),
        "attn_k": nk, "attn_v": nv,
    }
    return logits, new_cache, table


def declare_fold_slots(spec: DeviceFoldSpec, cfg: ModelConfig) -> None:
    spec.declare("app", "loss", "train_step", "count")
